"""Differential harness for the one-pass fused encode/decode pipeline.

The fused path (``kernels/fused.py`` on TPU, the NumPy oracles in
``kernels/ref.py`` on host — selected by ``ops.host_fastpath()``) must be
*bit-identical* to the legacy multi-pass composition it replaced: separate
delta/quantize kernels followed by a second checksum pass over the encoded
payload. This module is that proof, plus the integration layers above it:

* fused Pallas kernels vs primitive-kernel composition vs NumPy oracles;
* codec round-trips (``encode_delta_chunk`` / ``encode_int8_block``) across
  dtypes and odd sizes, digest self-consistency, tamper detection;
* the streaming whole-file checksum vs the manifest's read-back hash under
  adversarial write patterns;
* real ``FileWriter``/``FileReader`` round-trips with per-chunk digests;
* the encode-budget contract: the encoded footprint is reserved exactly
  once per chunk, before the encode allocates it, and every staged byte is
  read exactly once (``engine.bytes_encode_read``).

Property tests ride hypothesis when it is installed; the parametrized
fixed cases below are the fallback corpus and always run.
"""

import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codecs import (CodecError, INT8_ROW_BYTES, INT8_ROW_ELEMS,
                               DELTA_CODEC, INT8_CODEC, decode_chunk_payload,
                               decode_int8_block, encode_delta_chunk,
                               encode_int8_block, int8_encoded_nbytes,
                               payload_digest)
from repro.core.layout import FileLayout, FileReader, FileWriter
from repro.core.reduction import _compress
from repro.core.state_provider import (DeltaStateProvider, EncodeBudget,
                                       QuantizedStateProvider)
from repro.kernels import ops, ref
from repro.obs.metrics import metrics as obs_metrics
from repro.storage.file_format import StreamingFileChecksum
from repro.storage.manifest import file_checksum

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # the container has no hypothesis — the
    HAVE_HYPOTHESIS = False  # parametrized fixed cases are the corpus


def _bytes_case(nbytes: int, dtype, seed: int) -> np.ndarray:
    """Deterministic raw test bytes drawn through a typed array, so bit
    patterns exercise each dtype's value distribution (denormals, NaNs
    never matter — XOR/checksum are bit-domain)."""
    rng = np.random.default_rng(seed)
    if np.issubdtype(np.dtype(dtype), np.floating):
        arr = rng.standard_normal(-(-nbytes // np.dtype(dtype).itemsize)) \
            .astype(dtype)
    else:
        info = np.iinfo(dtype)
        arr = rng.integers(info.min, info.max,
                           -(-nbytes // np.dtype(dtype).itemsize),
                           dtype=dtype, endpoint=True)
    return arr.view(np.uint8)[:nbytes].copy()


# dtype sweep × odd sizes: u32-aligned, sub-word tail, single word, one byte
BYTE_CASES = [
    (65_536, np.float32), (70_004, np.float32),
    (12_345, np.int8), (7, np.int8),
    (4096, np.uint16),          # bf16-width lanes
    (99_991, np.uint32), (4, np.uint32), (1, np.uint8),
]


# ------------------------------------------------ fused kernels vs legacy
# Interpret-mode Pallas moves tens of MB/s — the arrays stay small; the
# codec-layer sweeps below cover size diversity at NumPy speed.
@pytest.mark.parametrize("n", [65_536, 70_000])
def test_fused_xor_checksum_matches_multipass(n):
    """One fused kernel call == legacy pass 1 (delta kernel) + legacy
    pass 2 (checksum kernel over the delta), bit for bit."""
    cur = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    prev = jax.random.normal(jax.random.PRNGKey(2), (n,), jnp.float32)
    delta_legacy = ops.delta_xor(cur, prev)           # pass 1
    dig_legacy = int(ops.tensor_checksum(delta_legacy))   # pass 2
    delta_fused, dig_fused = ops.fused_xor_checksum(cur, prev)
    np.testing.assert_array_equal(np.asarray(delta_fused),
                                  np.asarray(delta_legacy))
    assert int(dig_fused) == dig_legacy
    # and both equal the NumPy oracle the host fastpath dispatches to
    d_ref, dig_ref = ref.fused_xor_checksum_ref(
        np.asarray(cur).view(np.uint32), np.asarray(prev).view(np.uint32))
    np.testing.assert_array_equal(np.asarray(delta_fused)[:n], d_ref)
    assert int(dig_fused) == dig_ref


def test_fused_xor_fold_matches_multipass():
    """Fused decode: fold(base, delta) == base ^ delta with the digest of
    the *delta* (what the footer stores), matching the two-pass read."""
    n = 70_000
    base = jax.random.normal(jax.random.PRNGKey(3), (n,), jnp.float32)
    delta = jax.random.normal(jax.random.PRNGKey(4), (n,), jnp.float32)
    folded, dig = ops.fused_xor_fold(base, delta)
    want = np.bitwise_xor(np.asarray(base).view(np.uint32),
                          np.asarray(delta).view(np.uint32))
    np.testing.assert_array_equal(np.asarray(folded)[:n], want)
    assert int(dig) == int(ops.tensor_checksum(ops.as_u32(delta)))
    f_ref, dig_ref = ref.fused_xor_fold_checksum_ref(
        np.asarray(base).view(np.uint32), np.asarray(delta).view(np.uint32))
    np.testing.assert_array_equal(np.asarray(folded)[:n], f_ref)
    assert int(dig) == dig_ref


@pytest.mark.parametrize("rows", [256, 512])
def test_fused_quantize_matches_multipass(rows):
    """Fused quantize+digest vs the primitive quantize kernel plus a
    separate digest pass over what was actually emitted. q must be
    bit-exact; scales follow the repo's 1-ULP jit convention; the digest
    always describes the emitted (q, scales) payload area."""
    x = jax.random.normal(jax.random.PRNGKey(rows), (rows, INT8_ROW_ELEMS),
                          jnp.float32)
    q_legacy, s_legacy = ops.quantize_int8(x)     # legacy pass 1
    q, s, dig = ops.fused_quantize_int8(x, rows)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_legacy))
    np.testing.assert_allclose(np.asarray(s).reshape(-1),
                               np.asarray(s_legacy).reshape(-1), rtol=1e-6)
    # legacy pass 2 over the fused outputs == the fused digest
    assert int(dig) == ref.int8_payload_digest_ref(
        np.asarray(q), np.asarray(s), rows)


def test_fused_dequantize_matches_multipass():
    """int8→fp32 is one exactly-rounded multiply: the fused decode, the
    primitive kernel, and the oracle agree bit for bit — and the fused
    digest re-derives the stored payload's checksum during the decode."""
    rows = 256
    x = jax.random.normal(jax.random.PRNGKey(9), (rows, INT8_ROW_ELEMS),
                          jnp.float32)
    q, s = ops.quantize_int8(x)
    out, dig = ops.fused_dequantize_int8(q, s, rows)
    want = np.asarray(ops.dequantize_int8(q, s))
    np.testing.assert_array_equal(np.asarray(out), want)
    want_ref, dig_ref = ref.fused_dequantize_checksum_ref(
        np.asarray(q), np.asarray(s), rows)
    np.testing.assert_array_equal(np.asarray(out), want_ref)
    assert int(dig) == dig_ref == ref.int8_payload_digest_ref(
        np.asarray(q), np.asarray(s), rows)


def test_host_fastpath_checksum_equals_kernel():
    """The host fastpath's whole-tensor checksum (NumPy) and the Pallas
    checksum kernel are the same function."""
    for nbytes, dtype in [(70_004, np.float32), (12_345, np.int8)]:
        raw = _bytes_case(nbytes, dtype, seed=nbytes)
        assert ops.tensor_checksum_fast(raw) \
            == int(ops.tensor_checksum(jnp.asarray(raw)))


# -------------------------------------------------- codec layer round-trips
@pytest.mark.parametrize("nbytes,dtype", BYTE_CASES)
def test_delta_codec_roundtrip_and_digest(nbytes, dtype):
    cur = _bytes_case(nbytes, dtype, seed=10)
    prev = _bytes_case(nbytes, dtype, seed=11)
    delta, dig = encode_delta_chunk(cur, prev, with_digest=True)
    assert delta.nbytes == nbytes
    # digest == read-side oracle over the payload as stored
    assert dig == payload_digest(delta)
    # the no-digest path emits the identical payload
    delta2, dig2 = encode_delta_chunk(cur, prev, with_digest=False)
    assert dig2 is None
    np.testing.assert_array_equal(delta, delta2)
    # chain replay inverts it
    np.testing.assert_array_equal(np.bitwise_xor(prev, delta), cur)


@pytest.mark.parametrize("nbytes", [1 << 20, INT8_ROW_BYTES, 4097, 1000, 7])
def test_int8_codec_roundtrip_and_digest(nbytes):
    raw = _bytes_case((-(-nbytes // 4)) * 4, np.float32, seed=nbytes)[:nbytes]
    payload, dig = encode_int8_block(raw, with_digest=True)
    assert len(payload) == int8_encoded_nbytes(nbytes)
    # the fused digest covers the *whole* packed payload as stored —
    # header words included — so the read side can verify with one oracle
    assert dig == payload_digest(payload)
    out = decode_int8_block(payload, 0, nbytes, expect_digest=dig)
    assert out.nbytes == nbytes
    # bounded loss: one quantization step per fp32 value (whole rows only;
    # a sub-word tail can't view as fp32)
    if nbytes % 4 == 0:
        x = raw.view(np.float32)
        got = out.view(np.float32)
        pad = (-x.size) % INT8_ROW_ELEMS
        xp = np.concatenate([x, np.zeros(pad, np.float32)]) if pad else x
        step = np.abs(xp.reshape(-1, INT8_ROW_ELEMS)).max(axis=1) / 127
        step = np.repeat(step, INT8_ROW_ELEMS)[:x.size]
        assert (np.abs(got - x) <= step + 1e-7).all()
    # digest-off path: identical payload
    payload2, dig2 = encode_int8_block(raw, with_digest=False)
    assert dig2 is None and payload2 == payload


def test_int8_decode_rejects_tampered_payload():
    raw = _bytes_case(8192, np.float32, seed=77)
    payload, dig = encode_int8_block(raw, with_digest=True)
    # flip one bit inside the q area
    bad = bytearray(payload)
    bad[-100] ^= 0x40
    with pytest.raises(CodecError, match="digest mismatch"):
        decode_int8_block(bytes(bad), 0, 8192, expect_digest=dig)
    # a wrong stored digest is equally fatal
    with pytest.raises(CodecError, match="digest mismatch"):
        decode_int8_block(payload, 0, 8192,
                          expect_digest=(dig ^ 1) & 0xFFFFFFFF)
    # ...and without an expectation the decode still works (legacy footers)
    assert decode_int8_block(payload, 0, 8192).nbytes == 8192


def test_decode_dispatch_guards_chained_codecs():
    raw = _bytes_case(4096, np.float32, seed=5)
    payload, dig = encode_int8_block(raw, with_digest=True)
    out = decode_chunk_payload(INT8_CODEC, payload, 0, 4096,
                               expect_digest=dig)
    assert out.nbytes == 4096
    with pytest.raises(CodecError, match="chained"):
        decode_chunk_payload(DELTA_CODEC, b"\0" * 16, 0, 16)


# --------------------------------------------- streaming file checksum
def test_streaming_checksum_matches_manifest_hash(tmp_path):
    """The write-time accumulator must equal the manifest's read-back hash
    under every write pattern the writer produces: out-of-order pwrites,
    gaps (read as zeros), unaligned offsets/lengths, chunk-spanning runs."""
    patterns = [
        [(0, 123)],
        [(0, (4 << 20) + 517)],                      # spans a chunk seam
        [(4096, 1 << 16), (1 << 20, 77), (8, 3)],    # gap + out-of-order
        [(0, 4 << 20)],                              # exactly one chunk
        [(3, 7), (17, 1), (2 << 20, 4097)],          # unaligned everything
    ]
    for i, pat in enumerate(patterns):
        path = str(tmp_path / f"f{i}.bin")
        acc = StreamingFileChecksum()
        size = 0
        with open(path, "wb") as f:
            for j, (off, nb) in enumerate(pat):
                data = _bytes_case(nb, np.uint8, seed=100 * i + j)
                f.seek(off)
                f.write(data.tobytes())
                acc.update(off, data)
                size = max(size, off + nb)
            f.truncate(size)
        assert acc.value == file_checksum(path), f"pattern {i}: {pat}"


# ------------------------------------------- FileWriter/FileReader e2e
def _write_encoded_file(path, *, name, codec, chunks, nbytes,
                        track_checksum):
    """Drive the real writer the way a flush lane does: declare, compress,
    append with the fused digest, finalize."""
    w = FileWriter(path, FileLayout.plan([]), track_checksum=track_checksum)
    w.declare_encoded_tensor(name, dtype="uint8", shape=(nbytes,),
                             nbytes=nbytes, codec=codec)
    for payload, lo, hi, dig in chunks:
        w.append_encoded_chunk(name, _compress(bytes(payload)), lo, hi,
                               digest=dig)
    w.finalize()
    return w


def test_writer_reader_delta_roundtrip_with_digests(tmp_path):
    path = str(tmp_path / "d.dsllm")
    cur = _bytes_case(100_000, np.float32, seed=1)
    prev = _bytes_case(100_000, np.float32, seed=2)
    cut = 65_536
    chunks = []
    for lo, hi in [(0, cut), (cut, 100_000)]:
        delta, dig = encode_delta_chunk(cur[lo:hi], prev[lo:hi],
                                        with_digest=True)
        chunks.append((delta.tobytes(), lo, hi, dig))
    w = _write_encoded_file(path, name="t", codec=DELTA_CODEC,
                            chunks=chunks, nbytes=100_000,
                            track_checksum=True)
    # streamed == recomputed, without a second read of the file
    assert w.file_checksum == file_checksum(path)
    r = FileReader(path)
    entry = r.tensors["t"]
    assert [c[4] for c in entry.enc_chunks] == [c[3] for c in chunks]
    # tensor-level checksum derived for free from the chunk-digest fold
    want = 0
    for i, (_p, _lo, _hi, dig) in enumerate(chunks):
        want = (want + (i + 1) * dig) % (1 << 32)
    assert entry.checksum == want
    got = r.read_encoded_delta("t")
    np.testing.assert_array_equal(np.bitwise_xor(prev, got), cur)


def test_writer_reader_int8_roundtrip_with_digests(tmp_path):
    path = str(tmp_path / "q.dsllm")
    nbytes = 300_000
    raw = _bytes_case(nbytes, np.float32, seed=3)
    cut = 262_144  # a whole number of quantization rows
    chunks = []
    for lo, hi in [(0, cut), (cut, nbytes)]:
        payload, dig = encode_int8_block(raw[lo:hi], with_digest=True)
        chunks.append((payload, lo, hi, dig))
    w = _write_encoded_file(path, name="t", codec=INT8_CODEC,
                            chunks=chunks, nbytes=nbytes,
                            track_checksum=True)
    assert w.file_checksum == file_checksum(path)
    out = FileReader(path).read_encoded_tensor("t")
    assert out.nbytes == nbytes


def test_reader_rejects_tampered_shard(tmp_path):
    """Restore-side integrity: flipping one payload byte on disk fails the
    digest check during read, for both the chained and the self-contained
    codec."""
    for codec, make in [
        (DELTA_CODEC,
         lambda raw: encode_delta_chunk(raw, np.zeros_like(raw),
                                        with_digest=True)),
        (INT8_CODEC,
         lambda raw: encode_int8_block(raw, with_digest=True)),
    ]:
        path = str(tmp_path / f"{codec.split('+')[0]}.dsllm")
        raw = _bytes_case(65_536, np.float32, seed=4)
        payload, dig = make(raw)
        # the attack that only the fused digest can catch: a *validly
        # compressed* frame of a tampered payload, stored against the
        # original digest (a raw on-disk byte flip is already rejected by
        # the compression frame's own integrity check)
        bad = bytearray(bytes(payload))
        bad[len(bad) // 2] ^= 0x10
        w = FileWriter(path, FileLayout.plan([]))
        w.declare_encoded_tensor("t", dtype="uint8", shape=(65_536,),
                                 nbytes=65_536, codec=codec)
        w.append_encoded_chunk("t", _compress(bytes(bad)), 0, 65_536,
                               digest=dig)
        w.finalize()
        r = FileReader(path)
        with pytest.raises(ValueError, match="mismatch"):
            if codec == DELTA_CODEC:
                r.read_encoded_delta("t")
            else:
                r.read_encoded_tensor("t")


def test_legacy_four_tuple_footers_still_read(tmp_path):
    """Pre-digest footers carry 4-tuple enc_chunks; the reader normalizes
    them to digest=None and skips verification."""
    import msgpack
    path = str(tmp_path / "legacy.dsllm")
    raw = _bytes_case(4096, np.float32, seed=6)
    payload, _ = encode_int8_block(raw, with_digest=False)
    w = FileWriter(path, FileLayout.plan([]))
    w.declare_encoded_tensor("t", dtype="uint8", shape=(4096,),
                             nbytes=4096, codec=INT8_CODEC)
    w.append_encoded_chunk("t", _compress(bytes(payload)), 0, 4096)
    w.finalize()
    # rewrite the footer with 4-tuple chunks, as an old writer laid it out
    r = FileReader(path)
    footer = r.footer
    for t in footer["tensors"]:
        t["enc_chunks"] = [list(c[:4]) for c in t["enc_chunks"]]
    fpay = msgpack.packb(footer, use_bin_type=True)
    trailer = struct.Struct("<Q8s")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.seek(size - trailer.size)
        old_len, magic = trailer.unpack(f.read(trailer.size))
        f.seek(size - trailer.size - old_len)
        f.write(fpay)
        f.write(trailer.pack(len(fpay), magic))
        f.truncate()
    r2 = FileReader(path)
    assert r2.tensors["t"].enc_chunks[0][4] is None
    assert r2.read_encoded_tensor("t").nbytes == 4096


# -------------------------------------------------- encode-budget contract
class _RecordingBudget(EncodeBudget):
    def __init__(self, cap):
        super().__init__(cap)
        self.acquires = []
        self.peak = 0

    def acquire(self, nbytes):
        self.acquires.append(nbytes)
        super().acquire(nbytes)
        self.peak = max(self.peak, self._used)


def test_quantized_budget_reserves_encoded_footprint_once():
    """Regression for the double-reservation bug: each fused chunk must
    reserve exactly its *encoded* footprint (known a priori), exactly
    once — not once per legacy pass, and not the raw size."""
    n = 6 * INT8_ROW_BYTES + 4  # two full chunks + a one-row tail chunk
    arr = _bytes_case(n, np.float32, seed=8).view(np.float32)
    sp = QuantizedStateProvider("q", dtype="float32", shape=(arr.size,),
                                nbytes=n, host_array=arr,
                                chunk_bytes=3 * INT8_ROW_BYTES)
    budget = _RecordingBudget(cap=1 << 30)
    sp.encode_budget = budget
    spans = [(lo, min(lo + sp.chunk_bytes, n))
             for lo in range(0, n, sp.chunk_bytes)]
    want = [int8_encoded_nbytes(hi - lo) for lo, hi in spans]
    chunks = []
    for c in sp.chunks():
        chunks.append(c)
        assert len(c.data) == int8_encoded_nbytes(
            c.raw_range[1] - c.raw_range[0])
        c.on_flushed()  # flush lane credits back immediately
    assert budget.acquires == want
    # with immediate flush the pool never holds more than one chunk
    assert budget.peak == max(want)
    assert budget._used == 0


def test_delta_budget_and_single_read_of_staged_bytes():
    """A mixed delta save reads each staged byte exactly once
    (``engine.bytes_encode_read``), reserves each delta chunk once, and
    advances the snapshot base to the current bytes without re-reading
    the staged view."""
    n = 200_000
    cur = _bytes_case(n, np.float32, seed=12)
    prev_store = _bytes_case(n, np.float32, seed=13)
    prev_copy = prev_store.copy()
    sp = DeltaStateProvider("d", dtype="uint8", shape=(n,), nbytes=n,
                            host_array=cur, prev=memoryview(prev_store),
                            keyframe=False, chunk_bytes=65_536)
    sp.checksum_chunks = True
    budget = _RecordingBudget(cap=1 << 30)
    sp.encode_budget = budget
    before = obs_metrics.snapshot()["counters"] \
        .get("engine.bytes_encode_read", 0)
    out = []
    for c in sp.chunks():
        assert c.digest == payload_digest(np.asarray(c.data))
        out.append(c)
        c.on_flushed()
    read = obs_metrics.snapshot()["counters"]["engine.bytes_encode_read"] \
        - before
    assert read == n                       # one read per staged byte
    assert budget.acquires == [min(65_536, n - lo)
                               for lo in range(0, n, 65_536)]
    assert budget._used == 0
    # the chain base advanced to cur (base ^ delta), bit-exactly
    np.testing.assert_array_equal(prev_store, cur)
    # and the emitted deltas replay against the *old* base
    folded = prev_copy.copy()
    for c in out:
        lo, hi = c.raw_range
        np.bitwise_xor(folded[lo:hi], np.asarray(c.data),
                       out=folded[lo:hi])
    np.testing.assert_array_equal(folded, cur)


# ------------------------------------------------- property tests (bonus)
if HAVE_HYPOTHESIS:
    @given(st.integers(1, 65_536), st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=30, deadline=None)
    def test_prop_delta_digest_is_payload_digest(nbytes, seed):
        cur = _bytes_case(nbytes, np.uint8, seed=seed & 0xFFFF)
        prev = _bytes_case(nbytes, np.uint8, seed=(seed >> 16) | 1)
        delta, dig = encode_delta_chunk(cur, prev, with_digest=True)
        assert dig == payload_digest(delta)
        np.testing.assert_array_equal(np.bitwise_xor(prev, delta), cur)

    @given(st.integers(1, 32_768))
    @settings(max_examples=20, deadline=None)
    def test_prop_int8_payload_digest_roundtrip(nbytes):
        raw = _bytes_case((-(-nbytes // 4)) * 4, np.float32,
                          seed=nbytes)[:nbytes]
        payload, dig = encode_int8_block(raw, with_digest=True)
        assert dig == payload_digest(payload)
        assert decode_int8_block(payload, 0, nbytes,
                                 expect_digest=dig).nbytes == nbytes
