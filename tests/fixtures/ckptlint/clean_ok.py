"""Golden fixture: a module every rule family must pass untouched."""

import threading
import time

from repro.analysis.locks import declares_lock, named_lock


@declares_lock("fxc.outer", rank=10, attrs=("_lock",))
class Orchestrator:
    def __init__(self, repo):
        self._lock = threading.Lock()
        self.repo = repo
        self.count = 0

    def tick(self):
        with self._lock:
            self.count += 1
        time.sleep(0.0)  # blocking work happens outside the lock

    def nested_in_order(self):
        inner = named_lock("fxc.inner", rank=90)
        with self._lock:
            with inner:  # ranks strictly increase inward: legal
                self.count += 1

    def commit(self, step, payload):
        # repository-owned bytes go through the atomic helper
        self.repo._local.put(f"data/{step}/shard.bin", payload)
