"""Golden fixture: blocking-under-lock rule family (CKPT201)."""

import threading
import time

from repro.analysis.locks import declares_lock


@declares_lock("fxb.state", rank=40, attrs=("_lock", "_cond"))
class Flusher:
    def __init__(self, backend):
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self.backend = backend

    def bad_sleep(self):
        with self._lock:
            time.sleep(0.5)  # EXPECT:CKPT201

    def bad_io(self, path):
        with self._lock:
            with open(path) as f:  # EXPECT:CKPT201
                return f.read()

    def bad_backend_call(self, key, data):
        with self._lock:
            self.backend.put(key, data)  # EXPECT:CKPT201

    def bad_future_wait(self, fut):
        with self._lock:
            return fut.result()  # EXPECT:CKPT201

    def ok_own_condition_wait(self):
        # sanctioned: waiting on the condition that aliases the held lock
        with self._cond:
            self._cond.wait(timeout=1.0)
