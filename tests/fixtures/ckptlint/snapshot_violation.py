"""Golden fixture: snapshot-immutability rule family (CKPT401)."""


def bad_direct_mutation(cache):
    res = cache.reserve(1024)
    res.view[0:4] = b"oops"  # EXPECT:CKPT401
    return res


def bad_aliased_mutation(cache):
    res = cache.reserve(1024)
    staged = res.view
    staged[0:4] = b"oops"  # EXPECT:CKPT401
    return res
