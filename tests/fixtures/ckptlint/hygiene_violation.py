"""Golden fixture: API-hygiene rule family (CKPT501/502/503)."""

from repro.core.checkpoint import CheckpointManager
from repro.core.reduction import DifferentialCheckpointer  # EXPECT:CKPT503
from repro.core.state_provider import TensorStateProvider


def bad_api(tmpdir):
    mgr = CheckpointManager(tmpdir, mode="datastates", flush_threads=2)  # EXPECT:CKPT501
    prov = TensorStateProvider("w0", dtype="float32", shape=(2,), nbytes=8)  # EXPECT:CKPT502
    return mgr, prov
