"""Golden fixture: commit-protocol rule family (CKPT301/302/303/304)."""

import os

from repro.core.layout import FileWriter


def bad_raw_write(repo, payload):
    sdir = repo.step_dir(7)
    with open(os.path.join(sdir, "shard.bin"), "wb") as f:  # EXPECT:CKPT301
        f.write(payload)


def bad_rename(repo):
    sdir = repo.step_dir(7)
    os.rename(sdir + ".tmp", sdir)  # EXPECT:CKPT302


def bad_writer_lane(path, layout):
    writer = FileWriter(path, layout)  # EXPECT:CKPT303
    try:
        writer.append_object("state", b"x")
    except Exception:
        writer.finalize()  # EXPECT:CKPT304
