"""Golden fixture: suppression comments silence (but still count) findings."""

import time
import threading

from repro.analysis.locks import declares_lock


@declares_lock("fxs.state", rank=40, attrs=("_lock",))
class Suppressed:
    def __init__(self):
        self._lock = threading.Lock()

    def same_line_form(self):
        with self._lock:
            time.sleep(0.1)  # ckptlint: disable=CKPT201

    def line_above_form(self, sdir, payload):
        # fixture: exercising the comment-on-previous-line suppression form
        # ckptlint: disable=CKPT301
        with open(sdir + "/x.bin", "wb") as f:
            f.write(payload)
