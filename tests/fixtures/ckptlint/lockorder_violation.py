"""Golden fixture: lock-order rule family (CKPT101/102/103/104).

Never imported — only parsed by ckptlint. `EXPECT:RULE` markers name the
finding each line must produce (tests/test_ckptlint.py reads them).
"""

import threading

from repro.analysis.locks import declares_lock, named_lock


@declares_lock("fx.state", rank=40, attrs=("_lock",))
class Holder:
    def __init__(self):
        self._lock = threading.Lock()
        self._extra = threading.Lock()  # EXPECT:CKPT103


def bad_nesting():
    hi = named_lock("fx.high", rank=50)
    lo = named_lock("fx.low", rank=10)
    with hi:
        with lo:  # EXPECT:CKPT101 EXPECT:CKPT102
            pass


def reverse_path():
    # the rank-legal direction; combined with bad_nesting this closes a
    # cycle in the acquisition graph
    hi = named_lock("fx.high", rank=50)
    lo = named_lock("fx.low", rank=10)
    with lo:
        with hi:
            pass


def bare_acquire():
    guard = named_lock("fx.bare", rank=60)
    guard.acquire()  # EXPECT:CKPT104
    print("no try/finally protects the release below")
    guard.release()
