"""Beyond-paper perf features compile + stay numerically exact under a real
(virtual-device) mesh: decode_kv_seq_shard, ulysses_attention, fsdp mode.

Each runs in a subprocess with 8 CPU devices (4×2 data×model mesh) on a
smoke-size model and checks (a) the step lowers+compiles with the feature
on, and (b) outputs match the feature-off build bit-for-bit (sharding must
never change math).
"""

import pytest

# Whole-module slow marker: multi-second jit compiles per case; the
# fast lane (scripts/run_tests.sh --fast) deselects these.
pytestmark = pytest.mark.slow

from conftest import run_in_subprocess

_COMMON = r"""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, smoke_variant
from repro.models import model as M
from repro.sharding import context as shctx
from repro.sharding.partition import batch_pspecs, param_pspecs, shardings_for
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
"""


def test_ulysses_attention_matches_baseline_under_mesh():
    out = run_in_subprocess(_COMMON + r"""
cfg0 = dataclasses.replace(smoke_variant(get_config("starcoder2-7b")),
                           n_heads=4, n_kv_heads=1, window=0,
                           layer_groups=((("full",), 2),))
cfg1 = dataclasses.replace(cfg0, ulysses_attention=True)
params = M.init_params(cfg0, jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 256),
                                      0, cfg0.vocab)}
outs = {}
with shctx.activate(mesh):
    for name, cfg in (("base", cfg0), ("ulysses", cfg1)):
        pspec = param_pspecs(cfg, params, mesh)
        ps = jax.device_put(params, shardings_for(pspec, mesh))
        bs = {k: jax.device_put(v, NamedSharding(mesh, s))
              for (k, v), s in zip(batch.items(),
                                   batch_pspecs(cfg, "train", batch,
                                                mesh).values())}
        f = jax.jit(lambda p, b, cfg=cfg: M.forward(cfg, p, b)[0])
        outs[name] = np.asarray(f(ps, bs), dtype=np.float32)
np.testing.assert_allclose(outs["base"], outs["ulysses"],
                           rtol=2e-2, atol=2e-2)
assert np.isfinite(outs["ulysses"]).all()
print("ULYSSES-OK")
""")
    assert "ULYSSES-OK" in out


def test_fsdp_mode_train_step_under_mesh():
    out = run_in_subprocess(_COMMON + r"""
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.sharding.partition import opt_pspecs
from repro.training.loop import make_train_step

cfg = dataclasses.replace(smoke_variant(get_config("llama2-7b")),
                          sharding_mode="fsdp", vocab=512, d_model=256)
params = M.init_params(cfg, jax.random.PRNGKey(0))
opt = init_opt_state(params)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64),
                                      0, cfg.vocab),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 64),
                                      0, cfg.vocab)}
with shctx.activate(mesh):
    shctx.set_batch_axes(("data", "model"))
    try:
        pshard = shardings_for(param_pspecs(cfg, params, mesh), mesh)
        oshard = shardings_for(opt_pspecs(cfg, params, mesh), mesh)
        ps = jax.device_put(params, pshard)
        os_ = jax.device_put(opt, oshard)
        bspec = batch_pspecs(cfg, "train", batch, mesh)
        bs = {k: jax.device_put(v, NamedSharding(mesh, bspec[k]))
              for k, v in batch.items()}
        step = jax.jit(make_train_step(cfg, AdamWConfig()))
        new_p, new_o, loss = step(ps, os_, bs)
        assert np.isfinite(float(loss)), loss
        # params are actually sharded over the full 8-device mesh
        w = jax.tree_util.tree_leaves(new_p)[1]
        assert len(w.sharding.device_set) == 8
    finally:
        shctx.set_batch_axes(None)
print("FSDP-OK", float(loss))
""")
    assert "FSDP-OK" in out


def test_decode_kv_seq_shard_matches_baseline_under_mesh():
    out = run_in_subprocess(_COMMON + r"""
from repro.serving.engine import make_decode_step, zero_caches
from repro.sharding.partition import cache_pspecs

cfg0 = smoke_variant(get_config("llama3.2-1b"))
cfg0 = dataclasses.replace(cfg0, layer_groups=((("full",), 2),))
cfg1 = dataclasses.replace(cfg0, decode_kv_seq_shard=True)
params = M.init_params(cfg0, jax.random.PRNGKey(0))
T = 256
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 1), 0, cfg0.vocab)
outs = {}
with shctx.activate(mesh):
    for name, cfg in (("base", cfg0), ("seqshard", cfg1)):
        caches = zero_caches(cfg, 8, T)
        cshard = shardings_for(cache_pspecs(cfg, caches, mesh,
                                            long_context=False), mesh)
        cs = jax.device_put(caches, cshard)
        step = jax.jit(make_decode_step(cfg))
        logits, _ = step(params, toks, cs, 5)
        outs[name] = np.asarray(logits, dtype=np.float32)
np.testing.assert_allclose(outs["base"], outs["seqshard"],
                           rtol=2e-2, atol=2e-2)
print("KVSEQ-OK")
""")
    assert "KVSEQ-OK" in out
