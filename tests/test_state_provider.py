"""Composable state providers: zero-copy streams, composition, ordering."""

import pickle

import numpy as np
import pytest

from repro.core.host_cache import HostCache
from repro.core.state_provider import (Chunk, CompositeStateProvider,
                                       ObjectStateProvider,
                                       TensorStateProvider)


def host_tsp(name, arr, **kw):
    return TensorStateProvider(name, dtype=str(arr.dtype), shape=arr.shape,
                               nbytes=arr.nbytes, host_array=arr, **kw)


def test_host_tensor_zero_copy_chunks():
    arr = np.arange(1000, dtype=np.float64)
    p = host_tsp("t", arr, chunk_bytes=1024)
    chunks = list(p.chunks())
    assert len(chunks) == (arr.nbytes + 1023) // 1024
    assert chunks[-1].last and not chunks[0].last
    joined = b"".join(bytes(c.data) for c in chunks)
    assert joined == arr.tobytes()
    # zero-copy: first chunk's memoryview aliases the source array
    assert chunks[0].data.obj is not None


def test_device_tensor_streams_as_staged():
    """Chunks become available incrementally as staging lands bytes."""
    cache = HostCache(1 << 20)
    p = TensorStateProvider("t", dtype="uint8", shape=(4096,), nbytes=4096,
                            chunk_bytes=1024)
    p.bind_reservation(cache.reserve(4096))
    src = np.random.default_rng(0).integers(0, 255, 4096, dtype=np.uint8)
    it = p.chunks()
    out = []
    for staged in (1024, 2048, 4096):
        dst = p.reservation.array(np.uint8, (4096,))
        dst[:staged] = src[:staged]
        p.notify_staged(staged)
        while len(out) * 1024 < staged:
            out.append(next(it))
    assert b"".join(bytes(c.data) for c in out) == src.tobytes()


def test_object_provider_lazy_serialization():
    calls = {"n": 0}

    class Tracked:
        def __reduce__(self):
            calls["n"] += 1
            return (dict, ())

    p = ObjectStateProvider("o", {"x": Tracked()})
    assert calls["n"] == 0          # nothing serialized at construction
    chunks = list(p.chunks())       # serialization happens at stream time
    assert calls["n"] == 1
    assert chunks[-1].last
    payload = b"".join(bytes(c.data) for c in chunks)
    assert pickle.loads(payload) == {"x": {}}
    assert p.serialized_nbytes == len(payload)


def test_preserialized_object_provider():
    payload = pickle.dumps([1, 2, 3])
    p = ObjectStateProvider("o", None, preserialized=payload)
    assert b"".join(bytes(c.data) for c in p.chunks()) == payload


def test_composite_orders_tensors_first_largest_first():
    a = host_tsp("small", np.zeros(10, np.uint8))
    b = host_tsp("big", np.zeros(10000, np.uint8))
    o = ObjectStateProvider("obj", {"k": 1})
    comp = CompositeStateProvider("f", [o, a, b])
    kinds = [(c.kind, c.name) for c in comp.chunks()]
    names = [n for _k, n in kinds]
    assert names.index("big") < names.index("small") < names.index("obj")


def test_composite_layout_assigns_offsets():
    a = host_tsp("a", np.zeros(100, np.uint8))
    b = host_tsp("b", np.zeros(200, np.uint8))
    comp = CompositeStateProvider("f", [a, b])
    layout = comp.plan_layout()
    assert {e.name for e in layout.tensors} == {"a", "b"}
    for c in comp.chunks():
        if c.kind == "tensor":
            assert c.offset is not None
        else:
            assert c.offset is None


def test_hierarchical_composition():
    inner = CompositeStateProvider("inner", [
        host_tsp("x", np.zeros(64, np.uint8)),
        ObjectStateProvider("io", 42)])
    outer = CompositeStateProvider("outer", [
        inner, host_tsp("y", np.zeros(128, np.uint8))])
    assert {p.name for p in outer.tensor_providers} == {"x", "y"}
    assert {p.name for p in outer.object_providers} == {"io"}
