"""Fault-injection harness for the multi-rank two-phase commit.

Acceptance (ISSUE 3): a killed/stalled rank at any protocol point must
leave the step invisible — no global manifest, ``latest_step`` falls back
to the previous committed step, restore resumes from it, and
``storage.cli verify`` exits non-zero — and training resumed afterwards
continues from the previous committed step.
"""

import glob
import os

import jax.numpy as jnp
import numpy as np
import pytest

from faults import FaultInjector, InjectedFault

from repro.analysis import witness as lock_witness
from repro.core import CheckpointError, CheckpointManager, latest_step, \
    step_dir
from repro.dist import BarrierBroken, CollectiveBarrier, Coordinator
from repro.storage import cli as storage_cli
from repro.storage.manifest import read_rank_manifests

WORLD = 3


@pytest.fixture(autouse=True)
def _lock_order_witness():
    """Every fault scenario runs under the runtime lock-order witness: the
    hierarchy declared in repro/analysis/locks.py must hold on the real
    interleavings these tests drive, not just lexically (ckptlint)."""
    with lock_witness.recording() as w:
        yield w
    w.assert_clean()


def tiny_state(tag: float = 0.0):
    return {"model": {f"w{i}": jnp.arange(256, dtype=jnp.float32) + tag + i
                      for i in range(2 * WORLD)},
            "meta": {"step": int(tag)}}


def manager_with_fault(tmp_path, injector, **kw):
    coord = Coordinator(WORLD, fault_hook=injector, ack_timeout_s=30.0,
                        checksum_files=kw.pop("checksum_files", True))
    return CheckpointManager(str(tmp_path), coordinator=coord, **kw)


def assert_step2_never_visible(root: str):
    """The shared acceptance block: step 2's save was killed, step 1 is
    the newest committed step, and the CLI flags the damage."""
    assert latest_step(root) == 1, "killed save became resume-eligible"
    with CheckpointManager(root) as mgr2:
        assert mgr2.latest_step() == 1
        out = mgr2.restore(tiny_state())
        assert mgr2.last_restored_step == 1
        assert float(out["model"]["w0"][1]) == 2.0  # tag 1.0 payload
    # non-zero exit gates automated resume (step 2 is an orphan)
    assert storage_cli.main(["--root", root, "verify"]) == 1
    # ...and GC with no grace reclaims exactly the victim
    assert storage_cli.main(["--root", root, "gc", "--orphans",
                             "--orphan-grace", "0"]) == 0
    assert not os.path.isdir(step_dir(root, 2))
    assert os.path.isdir(step_dir(root, 1))
    assert storage_cli.main(["--root", root, "verify"]) == 0


@pytest.mark.parametrize("point", ["mid_file", "after_upload", "before_ack"])
def test_killed_rank_leaves_no_commit(tmp_path, point):
    """Kill rank 1 at each window of the protocol: data without a vote,
    a truncated file, or a full vote without an ack — the global commit
    must be absent in every case."""
    injector = FaultInjector(point, rank=1, step=2)
    with manager_with_fault(tmp_path, injector) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)
        with pytest.raises(CheckpointError) as ei:
            mgr.save(2, tiny_state(2.0), blocking=True)
        assert isinstance(ei.value.__cause__, (InjectedFault, BarrierBroken))
        assert injector.fired.is_set()
        mgr.wait_for_commit()
        assert not mgr.repository.has_manifest(2)
    sdir = step_dir(str(tmp_path), 2)
    if point == "before_ack":
        # every byte on disk — all files, all votes — yet phase 2 never ran
        assert len(read_rank_manifests(sdir)) == WORLD
        assert len(glob.glob(os.path.join(sdir, "*.dsllm"))) == WORLD
    else:
        assert 1 not in read_rank_manifests(sdir)  # the victim never voted
    assert_step2_never_visible(str(tmp_path))


def test_stalled_rank_times_out_without_commit(tmp_path):
    """A stalled (not dead) rank: the coordinator's watchdog converts the
    missing ack into a save failure; the step stays invisible. Releasing
    the straggler later must not resurrect the step."""
    injector = FaultInjector("before_ack", rank=2, step=2, action="stall")
    # checksums off: the first Pallas checksum jit-compile could outlast
    # the deliberately tight 1s watchdog and kill the healthy step-1 save
    coord = Coordinator(WORLD, fault_hook=injector, ack_timeout_s=1.0,
                        checksum_files=False)
    with CheckpointManager(str(tmp_path), coordinator=coord) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)
        fut = mgr.save(2, tiny_state(2.0))
        with pytest.raises(CheckpointError) as ei:
            fut.wait_persisted(timeout=30)
        assert isinstance(ei.value.__cause__, TimeoutError)
        mgr.wait_for_commit()
        assert not mgr.repository.has_manifest(2)
        assert mgr.commit_errors == []  # aborted before commit, not during
        # let the straggler finish so drain()/close() can settle
        injector.release()
        mgr.drain()
        # the late ack hit a poisoned collective: still no manifest
        assert not mgr.repository.has_manifest(2)
    assert_step2_never_visible(str(tmp_path))


def test_commit_gate_rejects_tampered_step(tmp_path):
    """Phase 2 itself re-validates the votes: a vote deleted (or a stray
    undeclared shard added) between ack and commit fails the commit."""
    from repro.storage import CheckpointRepository, ManifestError
    with manager_with_fault(tmp_path, None) as mgr:
        mgr.save(1, tiny_state(1.0), blocking=True)
    sdir = step_dir(str(tmp_path), 1)
    repo = CheckpointRepository(str(tmp_path), auto_cascade=False)
    # stray shard no rank declared
    with open(os.path.join(sdir, "rank00099.dsllm"), "wb") as f:
        f.write(os.urandom(64))
    with pytest.raises(ManifestError, match="not\\s+declared"):
        repo.commit_step(1, expect_ranks=WORLD)
    os.unlink(os.path.join(sdir, "rank00099.dsllm"))
    # missing vote
    os.unlink(os.path.join(sdir, "rank00001.manifest.json"))
    with pytest.raises(ManifestError, match="missing"):
        repo.commit_step(1, expect_ranks=WORLD)
    repo.close()


@pytest.mark.slow
def test_resumed_training_continues_from_previous_step(tmp_path):
    """End to end: train with multi-rank checkpoints, kill the next save,
    and show a fresh trainer resumes from the last *committed* step and
    keeps training."""
    import dataclasses

    from repro.configs import get_config, smoke_variant
    from repro.training.loop import Trainer

    cfg = smoke_variant(get_config("llama2-7b"))
    injector = FaultInjector("after_upload", rank=0, step=4)
    with manager_with_fault(tmp_path, injector,
                            checksum_files=False) as mgr:
        tr = Trainer(cfg, batch=2, seq_len=16, manager=mgr)
        tr.run(2, ckpt_interval=2)       # step 2 commits
        mgr.wait_for_commit()
        assert mgr.latest_step() == 2
        with pytest.raises(CheckpointError):
            mgr.save(4, tr.state(), blocking=True)  # killed mid-save
        mgr.wait_for_commit()
        assert mgr.latest_step() == 2    # victim invisible

    # restart (the realistic post-fault path: a fresh process/world)
    with CheckpointManager(str(tmp_path), world=WORLD,
                           manifest_checksums=False) as mgr2:
        tr2 = Trainer(cfg, batch=2, seq_len=16, manager=mgr2)
        assert tr2.resume() == 2         # falls back to the committed step
        recs = tr2.run(2, ckpt_interval=2)  # training continues 3, 4
        assert recs[-1].step == 4
        assert np.isfinite(recs[-1].loss)
        mgr2.wait_for_commit()
        assert mgr2.latest_step() == 4   # and checkpoints again, multi-rank


def test_collective_barrier_poison_and_timeout():
    import threading

    b = CollectiveBarrier(2)
    results = []

    def party():
        try:
            results.append(b.wait(timeout=5))
        except BarrierBroken as exc:
            results.append(exc)

    t = threading.Thread(target=party)
    t.start()
    b.poison("rank 1 died", rank=1)
    t.join(timeout=5)
    assert isinstance(results[0], BarrierBroken)
    assert results[0].rank == 1
    with pytest.raises(BarrierBroken):
        b.wait()                 # stays broken until reset
    b.reset()
    t2 = threading.Thread(target=party)
    t2.start()
    assert b.wait(timeout=5) == 0
    t2.join(timeout=5)
    # observer timeout does not poison
    with pytest.raises(TimeoutError):
        b.wait_generation(5, timeout=0.05)
    assert not b.broken
