"""CheckpointManager: save/restore across all engines, consistency, dedup."""

import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CheckpointManager, ENGINES, FileReader,
                        load_snapshot_rank, load_sync_rank)


def make_state():
    return {
        "model": {"w1": jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32),
                  "w2": jnp.full((5, 3), 2.0, jnp.bfloat16)},
        "optimizer": {"m": jnp.zeros((64, 32)),
                      "count": jnp.array(7, jnp.int32)},
        "meta": {"step": 7, "lr": 1e-4, "rng_seed": [0, 1]},
        "host": np.arange(50, dtype=np.int16),
    }


@pytest.mark.parametrize("mode", sorted(ENGINES))
def test_save_all_engines(tmp_path, mode):
    state = make_state()
    with CheckpointManager(str(tmp_path), mode=mode,
                           host_cache_bytes=1 << 20) as mgr:
        fut = mgr.save(7, state)
        fut.wait_captured()
        fut.wait_persisted()
        assert fut.stats.bytes_tensors > 0
        assert fut.stats.n_tensors == 5  # w1, w2, m, count + host np array
        files = os.listdir(str(tmp_path / "global_step7"))
        assert files


@pytest.mark.parametrize("mode", sorted(ENGINES))
def test_restore_roundtrip(tmp_path, mode):
    state = make_state()
    with CheckpointManager(str(tmp_path), mode=mode) as mgr:
        mgr.save(7, state, blocking=True)
        out = mgr.restore(state, step=7)
        np.testing.assert_array_equal(np.asarray(out["model"]["w1"]),
                                      np.asarray(state["model"]["w1"]))
        np.testing.assert_array_equal(
            np.asarray(out["model"]["w2"], dtype=np.float32),
            np.asarray(state["model"]["w2"], dtype=np.float32))
        assert int(out["optimizer"]["count"]) == 7
        assert out["meta"] == state["meta"]
        np.testing.assert_array_equal(out["host"], state["host"])


def test_latest_step_and_multiple_checkpoints(tmp_path):
    state = make_state()
    with CheckpointManager(str(tmp_path)) as mgr:
        assert mgr.latest_step() is None
        mgr.save(1, state, blocking=True)
        mgr.save(5, state, blocking=True)
        mgr.save(3, state, blocking=True)
        assert mgr.latest_step() == 5


def test_restore_missing_raises(tmp_path):
    with CheckpointManager(str(tmp_path)) as mgr:
        with pytest.raises(FileNotFoundError):
            mgr.restore({}, step=None)


def test_sync_engine_file_is_plain_pickle(tmp_path):
    state = make_state()
    with CheckpointManager(str(tmp_path), mode="sync") as mgr:
        mgr.save(2, state, blocking=True)
    [f] = glob.glob(str(tmp_path / "global_step2" / "*.pkl"))
    graph = load_sync_rank(f)
    w1 = [v for kname, v in graph.items() if "w1" in kname]
    np.testing.assert_array_equal(w1[0]["data"],
                                  np.asarray(state["model"]["w1"]))
    assert graph["__objects__"]["state/meta/step"] == 7


def test_snapshot_engine_chunk_files(tmp_path):
    state = make_state()
    with CheckpointManager(str(tmp_path), mode="snapshot") as mgr:
        mgr.save(2, state, blocking=True)
    d = str(tmp_path / "global_step2")
    tensors = load_snapshot_rank(d, 0)
    w1 = [v for kname, v in tensors.items() if "w1" in kname]
    np.testing.assert_array_equal(w1[0], np.asarray(state["model"]["w1"]))


def test_snapshot_stats_count_files_once(tmp_path):
    """n_files must equal the number of files actually written (the seed
    double-counted: +1 per manifest, then +len(jobs) again)."""
    state = make_state()
    with CheckpointManager(str(tmp_path), mode="snapshot") as mgr:
        fut = mgr.save(2, state, blocking=True)
    n_on_disk = len(os.listdir(str(tmp_path / "global_step2")))
    assert fut.stats.n_files == n_on_disk


def test_sync_stats_count_files_once(tmp_path):
    state = make_state()
    with CheckpointManager(str(tmp_path), mode="sync") as mgr:
        fut = mgr.save(2, state, blocking=True)
    assert fut.stats.n_files == len(os.listdir(str(tmp_path / "global_step2")))


def test_snapshot_loader_buffer_sized_in_bytes(tmp_path):
    """load_snapshot_rank must size its buffer as shape*itemsize (the seed
    allocated prod(shape) uint8s first — wrong for any itemsize != 1)."""
    state = {"model": {"w": np.arange(1000, dtype=np.float64)},
             "meta": {"step": 1}}
    with CheckpointManager(str(tmp_path), mode="snapshot") as mgr:
        mgr.save(1, state, blocking=True)
    tensors = load_snapshot_rank(str(tmp_path / "global_step1"), 0)
    [w] = [v for k, v in tensors.items() if "model/w" in k]
    assert w.dtype == np.float64 and w.nbytes == 8000
    np.testing.assert_array_equal(w, np.arange(1000, dtype=np.float64))


def test_producer_error_aborts_writer_and_removes_partial_file(tmp_path):
    """A provider failure mid-stream must fail the future AND clean up the
    footer-less partial file instead of leaking the fd behind it."""
    from repro.core import (CheckpointError, CheckpointFuture,
                            DataMovementEngine, FilePlan, FileLayout)

    class ExplodingComposite:
        tensor_providers = ()

        def plan_layout(self):
            return FileLayout.plan([])

        def chunks(self):
            raise RuntimeError("provider exploded mid-stream")
            yield  # pragma: no cover - makes this a generator

    path = str(tmp_path / "boom.dsllm")
    eng = DataMovementEngine(host_cache_bytes=1 << 20, flush_threads=1)
    try:
        fut = CheckpointFuture(0, str(tmp_path))
        eng.submit([FilePlan(path, ExplodingComposite())], [], fut)
        with pytest.raises(CheckpointError):
            fut.wait_persisted(timeout=30)
        assert not os.path.exists(path), "partial file left behind"
    finally:
        eng.close()


def test_blocking_save_equivalent(tmp_path):
    state = make_state()
    with CheckpointManager(str(tmp_path)) as mgr:
        fut = mgr.save(9, state, blocking=True)
        assert fut.captured and fut.persisted


def test_footer_records_shard_metadata(tmp_path):
    state = make_state()
    with CheckpointManager(str(tmp_path)) as mgr:
        mgr.save(4, state, blocking=True)
    [f] = glob.glob(str(tmp_path / "global_step4" / "*.dsllm"))
    r = FileReader(f)
    names = r.tensor_names()
    w1 = [n for n in names if "w1" in n][0]
    e = r.tensors[w1]
    assert e.global_shape == (64, 32)
    assert e.index == ((0, 64), (0, 32))
    assert e.dtype == "float32"


def test_stats_phases_ordered(tmp_path):
    state = make_state()
    with CheckpointManager(str(tmp_path)) as mgr:
        fut = mgr.save(1, state)
        fut.wait_persisted()
        s = fut.stats
        assert s.t_captured <= s.t_persisted
        assert s.blocking_s >= 0
        assert s.total_bytes == s.bytes_tensors + s.bytes_objects
