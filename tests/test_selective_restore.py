"""Selective (per-domain) restore + the four stock providers end-to-end.

Acceptance for ISSUE 5: ``restore(domains=("model",))`` provably reads
only model-domain bytes (``RestoreStats.bytes_read`` audit), serving's
``load_params_for_serving`` rides the same path (including from a remote
tier), and tensor/object/delta/quantized all round-trip through one
registry-driven save on both the single-writer and ``world=4``
coordinator paths.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (CheckpointManager, CheckpointPolicy, DeltaPolicy,
                        DistPolicy, EnginePolicy, StateProviderRegistry,
                        StoragePolicy)
from repro.serving.engine import load_params_for_serving
from repro.storage import MemoryBackend, Tier
from repro.training.loop import Trainer


MODEL_BYTES = 64 * 32 * 4


def big_state(i=1):
    """Small model domain + much larger optimizer domain, so the
    bytes-read audit has a visible gap to measure."""
    return {
        "model": {"w": (jnp.arange(64 * 32, dtype=jnp.float32)
                        .reshape(64, 32) + i)},
        "optimizer": {"m": jnp.linspace(-2.0, 2.0, 512 * 256,
                                        dtype=jnp.float32)
                      .reshape(512, 256) * (1 + i),
                      "count": jnp.array(i, jnp.int32)},
        "ema": {"e": jnp.full((128, 64), float(i), jnp.float32)},
        "meta": {"step": i, "note": "x" * 1000},
    }


def four_provider_registry():
    return (StateProviderRegistry()
            .add_rule(provider="quantized", domain="optimizer",
                      dtype="float32")
            .add_rule(provider="delta", domain="ema")
            .add_rule(provider="tensor", domain="model")
            .add_rule(provider="auto"))


def assert_state_matches(out, i, quant_tol=True):
    ref = big_state(i)
    np.testing.assert_array_equal(np.asarray(out["model"]["w"]),
                                  np.asarray(ref["model"]["w"]))
    np.testing.assert_array_equal(np.asarray(out["ema"]["e"]),
                                  np.asarray(ref["ema"]["e"]))
    m, rm = np.asarray(out["optimizer"]["m"]), np.asarray(
        ref["optimizer"]["m"])
    if quant_tol:  # int8 per-row bound: one quantization step per value
        tol = np.abs(rm).max(axis=1, keepdims=True) / 127 + 1e-6
        assert np.all(np.abs(m - rm) <= tol)
    else:
        np.testing.assert_array_equal(m, rm)
    assert int(out["optimizer"]["count"]) == i
    assert out["meta"]["step"] == i


# ----------------------------------------------- acceptance: four providers
def test_four_stock_providers_roundtrip_single_writer(tmp_path):
    pol = CheckpointPolicy(engine=EnginePolicy(host_cache_bytes=1 << 24),
                           delta=DeltaPolicy(keyframe_every=3),
                           providers=four_provider_registry())
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        for i in range(1, 5):
            mgr.save(i, big_state(i), blocking=True)
        # step 2/4 are delta steps for the ema domain; every step restores
        for i in range(1, 5):
            assert_state_matches(mgr.restore(big_state(0), step=i), i)
        man = mgr.repository.manifest(4)
        doms = man.meta["domains"]
        assert doms["model"]["providers"] == ["tensor"]
        assert doms["ema"]["providers"] == ["delta"]
        # the fp32 moments quantize; the int32 counter rides "auto",
        # which under a DeltaPolicy resolves to the delta provider
        assert doms["optimizer"]["providers"] == ["delta", "quantized"]
        assert doms["meta"]["providers"] == ["object"]


def test_four_stock_providers_roundtrip_world4(tmp_path):
    pol = CheckpointPolicy(engine=EnginePolicy(host_cache_bytes=1 << 26),
                           dist=DistPolicy(world=4),
                           delta=DeltaPolicy(keyframe_every=2),
                           providers=four_provider_registry())
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        for i in range(1, 4):
            mgr.save(i, big_state(i), blocking=True)
        for i in range(1, 4):
            assert_state_matches(mgr.restore(big_state(0), step=i), i)
        man = mgr.repository.manifest(3)
        assert man.meta.get("world") == 4
        assert man.meta["domains"]["optimizer"]["providers"] == [
            "delta", "quantized"]


# -------------------------------------------------- bytes-minimal restore
def test_selective_restore_reads_only_model_bytes(tmp_path):
    state = big_state(2)
    with CheckpointManager.from_policy(
            str(tmp_path),
            CheckpointPolicy(engine=EnginePolicy(host_cache_bytes=1 << 24))
    ) as mgr:
        mgr.save(2, state, blocking=True)
        total = mgr.repository.manifest(2).total_bytes
        out = mgr.restore(big_state(0), step=2, domains=("model",))
        stats = mgr.last_restore_stats
        # the audit: exactly the model tensor's bytes, nothing else
        assert stats.bytes_read == MODEL_BYTES
        assert stats.bytes_read < total // 10
        assert stats.n_leaves == 1
        np.testing.assert_array_equal(np.asarray(out["model"]["w"]),
                                      np.asarray(state["model"]["w"]))
        # unrequested domains keep the template's values, untouched
        np.testing.assert_array_equal(np.asarray(out["ema"]["e"]),
                                      np.zeros((128, 64), np.float32))
        assert out["meta"]["step"] == 0


def test_selective_restore_multiple_domains_and_errors(tmp_path):
    state = big_state(1)
    with CheckpointManager.from_policy(str(tmp_path)) as mgr:
        mgr.save(1, state, blocking=True)
        out = mgr.restore(big_state(0), step=1, domains=("model", "meta"))
        assert out["meta"]["step"] == 1
        np.testing.assert_array_equal(np.asarray(out["model"]["w"]),
                                      np.asarray(state["model"]["w"]))
        with pytest.raises(KeyError, match="dataloader"):
            mgr.restore(big_state(0), step=1, domains=("dataloader",))
        with pytest.raises(ValueError, match="mapping"):
            mgr.restore([jnp.zeros(4)], step=1, domains=("model",))


def test_selective_restore_from_quantized_save_skips_optimizer_bytes(
        tmp_path):
    """Domain selection composes with encoded providers: the quantized
    optimizer payloads are never even decoded for a model-only restore."""
    pol = CheckpointPolicy(engine=EnginePolicy(host_cache_bytes=1 << 24),
                           providers=four_provider_registry(),
                           delta=DeltaPolicy(keyframe_every=2))
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        mgr.save(1, big_state(1), blocking=True)
        mgr.restore(big_state(0), step=1, domains=("model",))
        assert mgr.last_restore_stats.bytes_read == MODEL_BYTES


def test_tampered_quantized_shard_fails_restore_but_not_clean_domains(
        tmp_path):
    """Per-chunk fused digests localize corruption: flipping a byte inside
    a quantized optimizer payload fails `storage.cli verify` and any
    restore that decodes those bytes — while a model-only selective
    restore of the *same shard* still succeeds, because domain selection
    never reads the damaged chunk."""
    import glob
    import os

    from faults import tamper_file
    from repro.core import step_dir
    from repro.core.layout import FileReader
    from repro.storage import cli as storage_cli

    pol = CheckpointPolicy(engine=EnginePolicy(host_cache_bytes=1 << 24),
                           providers=four_provider_registry(),
                           delta=DeltaPolicy(keyframe_every=2))
    state = big_state(1)
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        mgr.save(1, state, blocking=True)
        mgr.wait_for_commit(1)
    sdir = step_dir(str(tmp_path), 1)
    [f] = glob.glob(os.path.join(sdir, "*.dsllm"))
    # aim the flip at the quantized optimizer tensor's first fused chunk
    r = FileReader(f)
    ent = next(t for t in r.tensors.values() if "int8q" in (t.codec or ""))
    assert ent.enc_chunks and ent.enc_chunks[0][4] is not None
    tamper_file(f, offset=ent.enc_chunks[0][0] + 5, nbytes=1)
    assert storage_cli.main(["--root", str(tmp_path), "verify"]) == 1
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr2:
        with pytest.raises(Exception):   # digest/frame check mid-decode
            mgr2.restore(big_state(0), step=1, domains=("optimizer",))
        out = mgr2.restore(big_state(0), step=1, domains=("model",))
        assert mgr2.last_restore_stats.bytes_read == MODEL_BYTES
        np.testing.assert_array_equal(np.asarray(out["model"]["w"]),
                                      np.asarray(state["model"]["w"]))


def test_serving_goes_through_selective_restore(tmp_path):
    state = big_state(3)
    with CheckpointManager.from_policy(str(tmp_path)) as mgr:
        mgr.save(3, state, blocking=True)
    params, stats = load_params_for_serving(
        str(tmp_path), {"w": jnp.zeros((64, 32), jnp.float32)})
    np.testing.assert_array_equal(np.asarray(params["w"]),
                                  np.asarray(state["model"]["w"]))
    assert stats.bytes_read == MODEL_BYTES


def test_serving_selective_restore_from_remote_tier(tmp_path):
    """Satellite: the bytes-minimal serving path works when the step only
    survives on a remote tier (re-hydration + ranged reads)."""
    remote = Tier("peer", MemoryBackend())
    state = big_state(5)
    pol = CheckpointPolicy(storage=StoragePolicy(tiers=(remote,)))
    with CheckpointManager.from_policy(str(tmp_path), pol) as mgr:
        mgr.save(5, state, blocking=True)
        mgr.repository.wait_cascaded()
        mgr.repository._delete_local_step(5)
        assert mgr.repository.local_steps() == []
        params, stats = load_params_for_serving(
            str(tmp_path), {"w": jnp.zeros((64, 32), jnp.float32)},
            repository=mgr.repository)
        np.testing.assert_array_equal(np.asarray(params["w"]),
                                      np.asarray(state["model"]["w"]))
        assert stats.bytes_read == MODEL_BYTES


# ------------------------------------------------------- trainer resume
def test_trainer_partial_resume_model_domain_only(tmp_path):
    """Trainer.resume(domains=...) rides the same selective path: the
    model reloads from the checkpoint, optimizer/meta stay current."""
    from repro.configs import get_config, smoke_variant
    cfg = smoke_variant(get_config("llama3.2-1b"))
    with CheckpointManager.from_policy(str(tmp_path)) as mgr:
        tr = Trainer(cfg, batch=2, seq_len=16, manager=mgr)
        tr.run(2, ckpt_interval=2)
        mgr.wait_for_persist()
        saved_params = tr.params
        tr2 = Trainer(cfg, batch=2, seq_len=16, manager=mgr, seed=1)
        step_before = tr2.step
        tr2.resume(domains=("model",))
        import jax
        for a, b in zip(jax.tree_util.tree_leaves(tr2.params),
                        jax.tree_util.tree_leaves(saved_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert tr2.step == step_before  # meta domain untouched
        model_bytes = sum(np.asarray(x).nbytes
                          for x in jax.tree_util.tree_leaves(saved_params))
        assert tr2.last_resume_stats.bytes_read == model_bytes
