"""Lazy non-blocking capture semantics (paper §V-A2, Fig 6(c,d))."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CheckpointManager, CacheFullError


def big_state(mb=8):
    n = mb * (1 << 20) // 4
    return {"model": {"w": jnp.arange(n, dtype=jnp.float32)},
            "meta": {"step": 0}}


def test_save_returns_before_persist_with_throttle(tmp_path):
    """With storage throttled, the blocking prologue must return long before
    persistence completes — the defining property of async checkpointing."""
    state = big_state(8)
    mgr = CheckpointManager(str(tmp_path), mode="datastates",
                            host_cache_bytes=64 << 20,
                            throttle_mbps=200.0)  # 8MB -> ≥40ms flush
    try:
        fut = mgr.save(1, state)
        blocking = fut.stats.blocking_s
        assert not fut.persisted or blocking < fut.stats.persist_latency_s
        fut.wait_persisted()
        assert fut.stats.persist_latency_s > blocking * 2
    finally:
        mgr.close()


def test_sync_engine_blocks_until_persisted(tmp_path):
    state = big_state(4)
    mgr = CheckpointManager(str(tmp_path), mode="sync")
    try:
        fut = mgr.save(1, state)
        assert fut.persisted  # sync: save() returns only when durable
    finally:
        mgr.close()


def test_wait_for_capture_before_update(tmp_path):
    """The barrier returns only after all device state left the device."""
    state = big_state(4)
    mgr = CheckpointManager(str(tmp_path), mode="datastates",
                            host_cache_bytes=64 << 20)
    try:
        fut = mgr.save(1, state)
        stall = mgr.wait_for_capture()
        assert fut.captured
        assert stall >= 0.0
    finally:
        mgr.close()


def test_capture_precedes_persist(tmp_path):
    state = big_state(8)
    mgr = CheckpointManager(str(tmp_path), mode="datastates",
                            host_cache_bytes=64 << 20, throttle_mbps=500.0)
    try:
        fut = mgr.save(1, state)
        fut.wait_persisted()
        assert fut.stats.t_captured <= fut.stats.t_persisted
    finally:
        mgr.close()


def test_cache_backpressure_second_checkpoint(tmp_path):
    """A second request larger than remaining cache waits for eviction
    (flush completion) instead of failing — bounded host memory."""
    state = big_state(8)
    mgr = CheckpointManager(str(tmp_path), mode="datastates",
                            host_cache_bytes=12 << 20,  # < 2 checkpoints
                            throttle_mbps=300.0)
    try:
        mgr.save(1, state)
        t0 = time.perf_counter()
        fut2 = mgr.save(2, state)     # must wait for step-1 eviction
        fut2.wait_persisted()
        assert fut2.persisted
    finally:
        mgr.close()


def test_oversized_checkpoint_fails_cleanly(tmp_path):
    state = big_state(8)
    mgr = CheckpointManager(str(tmp_path), mode="datastates",
                            host_cache_bytes=1 << 20)
    from repro.core import CheckpointError
    try:
        with pytest.raises((CheckpointError, CacheFullError)):
            mgr.save(1, state)
    finally:
        mgr.engine._engine.close()  # bypass drain (nothing was submitted)


def test_many_shards_exceeding_cache_fail_fast_not_deadlock(tmp_path):
    """Sum-of-shards > cache (each shard individually fits): the coalesced
    up-front reservation must raise, not block forever waiting for flushes
    that can never start (regression: fig07 full-scale hang)."""
    import jax.numpy as jnp
    from repro.core import CheckpointError
    state = {f"w{i}": jnp.ones((128, 1024), jnp.float32)  # 8 x 512 KiB
             for i in range(8)}
    mgr = CheckpointManager(str(tmp_path), mode="datastates",
                            host_cache_bytes=1 << 20)   # 1 MiB cache
    try:
        with pytest.raises((CheckpointError, CacheFullError)):
            mgr.save(1, state)
    finally:
        mgr.engine._engine.close()


def test_datastates_blocking_much_smaller_than_sync(tmp_path):
    """The paper's headline property: blocking time (what training sees) is
    far smaller for DataStates than for the synchronous engine."""
    state = big_state(16)
    times = {}
    for mode in ("sync", "datastates"):
        mgr = CheckpointManager(str(tmp_path / mode), mode=mode,
                                host_cache_bytes=64 << 20,
                                throttle_mbps=400.0)
        try:
            fut = mgr.save(1, state)
            times[mode] = fut.stats.blocking_s
            fut.wait_persisted()
        finally:
            mgr.close()
    assert times["datastates"] < times["sync"] / 2, times
