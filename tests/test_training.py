"""Training loop + checkpoint/restart determinism + optimizer + data."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import CheckpointManager
from repro.data.pipeline import SyntheticTokenPipeline
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state
from repro.training.loop import Trainer

# Whole-module slow marker: multi-second jit compiles per case; the
# fast lane (scripts/run_tests.sh --fast) deselects these.
pytestmark = pytest.mark.slow


def tiny_cfg():
    return smoke_variant(get_config("llama2-7b"))


def test_loss_decreases():
    tr = Trainer(tiny_cfg(), batch=2, seq_len=32,
                 hp=AdamWConfig(lr=3e-3, weight_decay=0.0))
    # repeat the same batch so the model can actually fit it
    batch = tr.pipeline.next_batch()
    tr.pipeline.next_batch = lambda: batch
    recs = tr.run(8)
    assert recs[-1].loss < recs[0].loss


def test_checkpoint_restart_is_deterministic(tmp_path):
    """Train 6 steps with a checkpoint at 3; a fresh trainer resumed from the
    checkpoint reproduces steps 4-6 losses exactly (globally consistent
    state: params + optimizer + data-iterator + step counter)."""
    cfg = tiny_cfg()
    mgr = CheckpointManager(str(tmp_path), mode="datastates")
    tr1 = Trainer(cfg, batch=2, seq_len=32, manager=mgr)
    recs1 = tr1.run(6, ckpt_interval=3)
    losses_after_3 = [r.loss for r in recs1 if r.step > 3]

    tr2 = Trainer(cfg, batch=2, seq_len=32, manager=mgr)
    resumed_step = tr2.resume(step=3)
    assert resumed_step == 3
    recs2 = tr2.run(3)
    losses_replayed = [r.loss for r in recs2]
    np.testing.assert_allclose(losses_replayed, losses_after_3,
                               rtol=1e-6, atol=1e-6)
    mgr.close()


def test_restart_across_engine_modes(tmp_path):
    """Checkpoints written by datastates-old restore identically."""
    cfg = tiny_cfg()
    mgr = CheckpointManager(str(tmp_path), mode="datastates-old")
    tr = Trainer(cfg, batch=2, seq_len=16, manager=mgr)
    tr.run(2, ckpt_interval=2)
    mgr.wait_for_persist()
    tr2 = Trainer(cfg, batch=2, seq_len=16, manager=mgr)
    tr2.resume()
    w1 = jax.tree_util.tree_leaves(tr.params)[0]
    w2 = jax.tree_util.tree_leaves(tr2.params)[0]
    np.testing.assert_array_equal(np.asarray(w1, dtype=np.float32),
                                  np.asarray(w2, dtype=np.float32))
    mgr.close()


def test_lazy_stall_accounted():
    cfg = tiny_cfg()
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, mode="datastates")
        tr = Trainer(cfg, batch=2, seq_len=16, manager=mgr)
        recs = tr.run(4, ckpt_interval=1)
        assert any(r.ckpt_requested for r in recs)
        assert all(r.ckpt_stall_s >= 0 for r in recs)
        mgr.close()


# ------------------------------------------------------------------ optimizer
def test_adamw_minimizes_quadratic():
    hp = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, opt = apply_updates(params, opt, g, hp)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_keeps_fp32_master_for_bf16_params():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = init_opt_state(params)
    assert opt["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    new_params, new_opt = apply_updates(params, opt, g, AdamWConfig())
    assert new_params["w"].dtype == jnp.bfloat16
    assert new_opt["master"]["w"].dtype == jnp.float32
    # master moved even though the bf16 rounding may hide it
    assert float(jnp.abs(new_opt["master"]["w"] - 1.0).max()) > 0


def test_grad_clip_applies():
    hp = AdamWConfig(lr=1.0, grad_clip=1e-6, weight_decay=0.0)
    params = {"w": jnp.zeros((2,))}
    opt = init_opt_state(params)
    g = {"w": jnp.array([1e6, 1e6])}
    new_params, _ = apply_updates(params, opt, g, hp)
    assert float(jnp.abs(new_params["w"]).max()) < 2.0  # clipped, not 1e6


# ----------------------------------------------------------------------- data
def test_pipeline_deterministic_and_restorable():
    cfg = tiny_cfg()
    p1 = SyntheticTokenPipeline(cfg, 2, 16, seed=7)
    batches = [p1.next_batch() for _ in range(3)]
    state_after_2 = {"seed": 7, "step": 2}
    p2 = SyntheticTokenPipeline(cfg, 2, 16, seed=7)
    p2.restore(state_after_2)
    np.testing.assert_array_equal(p2.next_batch()["tokens"],
                                  batches[2]["tokens"])


def test_pipeline_shapes_for_modalities():
    for arch in ("paligemma-3b", "musicgen-medium"):
        cfg = smoke_variant(get_config(arch))
        p = SyntheticTokenPipeline(cfg, 2, 16)
        b = p.next_batch()
        if cfg.n_codebooks:
            assert b["tokens"].shape == (2, 16, cfg.n_codebooks)
            assert "memory_embeds" in b
        if cfg.n_prefix_embeds:
            assert b["prefix_embeds"].shape == (2, cfg.n_prefix_embeds,
                                                cfg.d_model)
