"""Perf hillclimb runner: compile variants of one (arch × shape) pair and
compare roofline terms against the baseline.

    PYTHONPATH=src python scripts/hillclimb.py --arch llama3.2-1b \
        --shape decode_32k --label kvblock2048 --set attn_kv_block=2048

Each invocation runs ONE variant in a fresh process (XLA device-count flag
must be set before jax imports — dryrun.py handles that) and appends the
record to experiments/perf/<arch>_<shape>.jsonl. ``--label baseline``
(or ``--mode tp_zero1 --label paper``) records reference points.
"""

import argparse
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--label", required=True)
    ap.add_argument("--mode", default="2d")
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--hypothesis", default="",
                    help="recorded alongside the result")
    args = ap.parse_args()

    out_dir = os.path.join(ROOT, "experiments", "perf")
    os.makedirs(out_dir, exist_ok=True)
    tmp_json = os.path.join(out_dir, f".{args.arch}_{args.shape}_{args.label}.json")

    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", args.arch, "--shape", args.shape,
           "--mode", args.mode, "--out", tmp_json]
    for s in args.set:
        cmd += ["--set", s]
    if args.no_donate:
        cmd += ["--no-donate"]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(cmd, env=env, cwd=ROOT)
    if r.returncode != 0:
        print(f"variant {args.label} FAILED to compile", file=sys.stderr)
        return 1

    with open(tmp_json) as f:
        rec = json.load(f)
    os.remove(tmp_json)
    rec["label"] = args.label
    rec["hypothesis"] = args.hypothesis
    log = os.path.join(out_dir, f"{args.arch}_{args.shape}.jsonl")
    with open(log, "a") as f:
        f.write(json.dumps(rec) + "\n")

    # print comparison against every prior entry
    entries = [json.loads(l) for l in open(log)]
    base = entries[0]
    bt = base["roofline"]["terms"]
    print(f"\n{'label':<22}{'compute':>10}{'memory':>10}{'collective':>12}"
          f"{'dominant':<14}{'Δdom vs base':>13}")
    for e in entries:
        t = e["roofline"]["terms"]
        dom = e["roofline"]["dominant"]
        delta = (t[base["roofline"]["dominant"]]
                 / max(bt[base["roofline"]["dominant"]], 1e-12) - 1) * 100
        print(f"{e['label']:<22}{t['compute_s']:>10.3f}{t['memory_s']:>10.3f}"
              f"{t['collective_s']:>12.3f}  {dom.replace('_s',''):<12}"
              f"{delta:>+12.1f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
