#!/usr/bin/env bash
# ckptlint entry point: the project-native static analyzer that enforces
# the concurrency + commit-protocol invariants (see README "Correctness
# tooling"). Non-zero exit on any active finding — this is a merge gate.
#
#   scripts/lint.sh                 # lint src/ (the gate)
#   scripts/lint.sh path [path...]  # lint specific files/dirs
#   scripts/lint.sh --list-rules    # rule catalog
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
  python -m repro.analysis "$@"
