"""Assemble the §Roofline table from experiments/dryrun/*.json.

    PYTHONPATH=src python scripts/roofline_report.py [--pod pod] [--md]

Reads every single-pod dry-run record, prints the three roofline terms,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and a one-line "what
would move the dominant term" note per (arch × shape).
"""

import argparse
import glob
import json
import os

NOTES = {
    ("compute_s",): "compute-bound: already near the best case; further "
                    "gains need lower-precision matmuls or fewer layers",
    ("memory_s",): "HBM-bound: reduce bytes moved — less remat recompute, "
                   "fused ops, or larger per-device tiles (less padding)",
    ("collective_s",): "ICI-bound: reshard to cut all-gather/all-reduce "
                       "volume or overlap collectives with compute",
}


def load(pod: str):
    recs = []
    for p in sorted(glob.glob(f"experiments/dryrun/*_{pod}.json")):
        with open(p) as f:
            r = json.load(f)
        recs.append(r)
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:7.2f}s"
    return f"{x*1e3:6.1f}ms"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pod", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--md", action="store_true", help="markdown table")
    args = ap.parse_args()
    recs = load(args.pod)
    shape_order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
                   "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], shape_order.get(r["shape"], 9)))

    if args.md:
        print("| arch | shape | compute | memory | collective | dominant | "
              "useful FLOPs | note |")
        print("|---|---|---|---|---|---|---|---|")
    else:
        print(f"{'arch':<26}{'shape':<13}{'compute':>10}{'memory':>10}"
              f"{'collect.':>10}  {'dominant':<13}{'useful':>7}")

    for r in recs:
        if r.get("skipped"):
            line = (f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — "
                    f"| {r['reason'][:60]} |") if args.md else \
                   (f"{r['arch']:<26}{r['shape']:<13}  SKIPPED: "
                    f"{r['reason'][:70]}")
            print(line)
            continue
        t = r["roofline"]["terms"]
        dom = r["roofline"]["dominant"]
        ratio = r["roofline"]["useful_flops_ratio"]
        note = NOTES[(dom,)]
        if args.md:
            print(f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
                  f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
                  f"{dom.replace('_s','')} | {ratio:.2f} | {note} |")
        else:
            print(f"{r['arch']:<26}{r['shape']:<13}{fmt_s(t['compute_s']):>10}"
                  f"{fmt_s(t['memory_s']):>10}{fmt_s(t['collective_s']):>10}"
                  f"  {dom.replace('_s',''):<13}{ratio:>7.2f}")

    done = sum(1 for r in recs if not r.get("skipped"))
    skipped = sum(1 for r in recs if r.get("skipped"))
    print(f"\n{done} compiled + {skipped} documented skips "
          f"({args.pod} mesh)")


if __name__ == "__main__":
    main()
