"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from
experiments/dryrun/*.json.

    PYTHONPATH=src python scripts/experiments_report.py > /tmp/sections.md
"""

import glob
import json
import os

HBM = 819e9
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCHS = ["dbrx-132b", "rwkv6-7b", "starcoder2-7b", "recurrentgemma-2b",
         "musicgen-medium", "gemma3-27b", "llama3.2-1b", "paligemma-3b",
         "llama4-maverick-400b-a17b", "command-r-35b", "llama2-7b"]


def load(arch, shape, pod):
    p = f"experiments/dryrun/{arch}_{shape}_{pod}.json"
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def mem_lb(r):
    m = r["roofline"]["memory"]
    if "argument_size_in_bytes" not in m:
        return None
    return (m["argument_size_in_bytes"] + m["output_size_in_bytes"]
            - m["alias_size_in_bytes"]) / HBM


def fmt(x, unit="ms"):
    if x is None:
        return "—"
    v = x * 1e3
    return f"{v:,.1f}" if v < 10_000 else f"{v:,.0f}"


def dryrun_section():
    print("## §Dry-run — multi-pod compile proof\n")
    print("Every (architecture × input shape) lowered + compiled with"
          " `jax.jit(...).lower().compile()` against BOTH production meshes:"
          " single-pod `16×16 (data, model)` = 256 chips and multi-pod"
          " `2×16×16 (pod, data, model)` = 512 chips. ✓ = compiled;"
          " `skip` = documented long-context skip (DESIGN.md §4); numbers"
          " are compile seconds.\n")
    print("| arch | shape | 16×16 | 2×16×16 | per-dev GiB (rolled, 512-chip) |")
    print("|---|---|---|---|---|")
    n_ok = n_skip = n_miss = 0
    for a in ARCHS:
        for s in SHAPES:
            rp, rm = load(a, s, "pod"), load(a, s, "multipod")
            cells = []
            byt = "—"
            for r in (rp, rm):
                if r is None:
                    cells.append("⏳")
                    n_miss += 1
                elif r.get("skipped"):
                    cells.append("skip")
                    n_skip += 1
                else:
                    cells.append(f"✓ {r['compile_s']:.0f}s")
                    n_ok += 1
            # fit-proof column: the ROLLED (multipod) compile — production
            # runs use scan+remat; the unrolled single-pod build exists only
            # for true FLOP counting and its temp bytes are not meaningful.
            if rm and not rm.get("skipped"):
                m = rm["roofline"]["memory"]
                tot = (m.get("argument_size_in_bytes", 0)
                       + m.get("temp_size_in_bytes", 0)
                       + m.get("output_size_in_bytes", 0)
                       - m.get("alias_size_in_bytes", 0))
                byt = f"{tot/2**30:.2f}"
            print(f"| {a} | {s} | {cells[0]} | {cells[1]} | {byt} |")
    print(f"\n**{n_ok} compiles OK, {n_skip//1} skips documented, "
          f"{n_miss} pending.** Skips: `long_500k` on pure full-attention"
          " archs (dbrx, musicgen, llama3.2, paligemma, command-r) — "
          "sub-quadratic attention required; runs on SSM/hybrid/windowed"
          " archs (rwkv6, recurrentgemma, starcoder2, gemma3, llama4) per"
          " DESIGN.md §4.\n")


def roofline_section():
    print("## §Roofline — single-pod (16×16, 256 chips) terms\n")
    print("v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI."
          " `mem_ub` = cost-analysis bytes (upper bound: XLA:CPU bf16"
          " emulation inflates it); `mem_lb` = args+outputs−aliases"
          " (guaranteed traffic). `dominant` uses the conservative ub;"
          " `eff` = MODEL_FLOPS/HLO_FLOPs (useful-compute fraction).\n")
    print("| arch | shape | compute | mem_lb | mem_ub | collective | "
          "dominant | eff | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            r = load(a, s, "pod")
            if r is None:
                print(f"| {a} | {s} | ⏳ | | | | | | |")
                continue
            if r.get("skipped"):
                print(f"| {a} | {s} | — | — | — | — | skip | — | "
                      f"full-attention arch |")
                continue
            t = r["roofline"]["terms"]
            lb = mem_lb(r)
            dom = r["roofline"]["dominant"].replace("_s", "")
            eff = r["roofline"]["useful_flops_ratio"]
            # realistic bottleneck: max(compute, mem_lb, collective)
            cand = {"compute": t["compute_s"], "memory": lb or 0,
                    "collective": t["collective_s"]}
            real = max(cand, key=cand.get)
            note = f"lb-dominant: {real}"
            print(f"| {a} | {s} | {fmt(t['compute_s'])} | {fmt(lb)} | "
                  f"{fmt(t['memory_s'])} | {fmt(t['collective_s'])} | "
                  f"{dom} | {eff:.2f} | {note} |")
    print()


if __name__ == "__main__":
    dryrun_section()
    roofline_section()
