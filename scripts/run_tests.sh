#!/usr/bin/env bash
# Tier-1 test suite (the command ROADMAP.md pins). Usage:
#   scripts/run_tests.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
