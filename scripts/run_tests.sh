#!/usr/bin/env bash
# Test-suite entry points.
#
# Tier-1 (the command ROADMAP.md pins — the FULL suite, slow tests
# included; this is what gates a PR):
#   scripts/run_tests.sh [extra pytest args...]
#
# Fast lane (~seconds-per-file iteration loop; deselects tests marked
# `slow` in pytest.ini — the multi-minute subprocess-mesh and end-to-end
# system/benchmark-shaped tests). CI runs this on every job and the full
# suite in a separate job:
#   scripts/run_tests.sh --fast [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
args=()
if [[ "${1:-}" == "--fast" ]]; then
  shift
  args+=(-m "not slow")
  # The fast lane is the iteration loop: run the ckptlint gate up front
  # so an invariant violation fails in ~a second, before any test runs.
  scripts/lint.sh
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
  python -m pytest -x -q ${args[@]+"${args[@]}"} "$@"
