"""Checkpoint-engine tuning sweep (§Perf, checkpoint side): chunk size ×
flush threads vs effective blocking throughput, DataStates engine.

    PYTHONPATH=src python scripts/ckpt_tuning.py

Hypothesis grid: larger chunks amortize per-chunk dispatch overhead until
they defeat pipelining (fewer in-flight units than threads); more threads
help until the (throttled) storage path saturates. Records to
experiments/perf/ckpt_tuning.json.
"""

import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CheckpointManager

PAYLOAD_MB = 256
THROTTLE = 600.0  # MB/s per thread — emulated PFS share


def make_state(mb: int):
    n = mb * (1 << 20) // 4
    rng = np.random.default_rng(0)
    host = rng.normal(size=(n // 2,)).astype(np.float32)
    dev = jnp.asarray(rng.normal(size=(n // 2,)).astype(np.float32))
    return {"host": host, "dev": dev,
            "meta": {"step": 1, "cfg": {"lr": 1e-4}}}


def run_one(state, chunk_mb: int, threads: int) -> dict:
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, mode="datastates",
                                host_cache_bytes=1 << 30,
                                chunk_bytes=chunk_mb << 20,
                                flush_threads=threads,
                                throttle_mbps=THROTTLE)
        t0 = time.perf_counter()
        fut = mgr.save(1, state)
        blocking = time.perf_counter() - t0
        t1 = time.perf_counter()
        fut.wait_captured()
        capture = time.perf_counter() - t1
        fut.wait_persisted()
        persist = time.perf_counter() - t0
        mgr.close()
    nbytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(state)
                 if hasattr(x, "nbytes"))
    return {"chunk_mb": chunk_mb, "threads": threads,
            "blocking_s": blocking, "capture_s": capture,
            "persist_s": persist,
            "blocking_tput_gbps": nbytes / max(blocking + capture, 1e-9) / 1e9,
            "persist_tput_gbps": nbytes / max(persist, 1e-9) / 1e9}


def main():
    state = make_state(PAYLOAD_MB)
    rows = []
    print(f"{'chunk':>6}{'thr':>4}{'block(ms)':>11}{'capture(ms)':>12}"
          f"{'persist(s)':>11}{'persist GB/s':>13}")
    for chunk_mb in (1, 4, 16, 64):
        for threads in (1, 2, 4, 8):
            r = run_one(state, chunk_mb, threads)
            rows.append(r)
            print(f"{chunk_mb:>6}{threads:>4}{r['blocking_s']*1e3:>11.1f}"
                  f"{r['capture_s']*1e3:>12.1f}{r['persist_s']:>11.2f}"
                  f"{r['persist_tput_gbps']:>13.2f}")
    out = os.path.join("experiments", "perf", "ckpt_tuning.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"payload_mb": PAYLOAD_MB, "throttle_mbps": THROTTLE,
                   "rows": rows}, f, indent=2)
    print("wrote", out)


if __name__ == "__main__":
    main()
