"""Debug: compile one (arch × shape) variant and print the largest-result
HLO ops + fusion count — the 'profile' for dry-run hillclimbing.

    PYTHONPATH=src python scripts/hlo_top_ops.py --arch X --shape Y \
        [--set k=v ...] [--mode 2d] [--top 25]
"""

import argparse
import collections
import re
import sys

sys.argv_backup = list(sys.argv)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mode", default="2d")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--set", action="append", default=[])
    args = ap.parse_args()

    from repro.launch import dryrun as D
    from repro.launch.analysis import shape_bytes

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = (v.lower() == "true" if v.lower() in ("true", "false")
                        else int(v) if v.lstrip("-").isdigit() else v)

    import io
    import contextlib
    import json
    import jax
    from repro.configs import INPUT_SHAPES, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch import analysis
    from repro.sharding import context as shctx
    from repro.optim.adamw import AdamWConfig
    from repro.serving.engine import make_decode_step, make_prefill_step
    from repro.training.loop import make_train_step

    shape = INPUT_SHAPES[args.shape]
    kvb = min(4096, max(1024, shape.seq_len // 8))
    kw = {"sharding_mode": args.mode, "analysis_unroll": True,
          "attn_kv_block": kvb}
    kw.update(overrides)
    cfg = get_config(args.arch, **kw)
    mesh = make_production_mesh(multi_pod=False)
    with shctx.activate(mesh):
        long_ctx = (shape.kind == "decode" and shape.seq_len > 100_000)
        shctx.set_seq_axis("data" if long_ctx else None)
        try:
            specs, in_sh, meta = D.input_specs(cfg, shape, mesh)
            if shape.kind == "train":
                step, dn = make_train_step(cfg, AdamWConfig()), (0, 1)
            elif shape.kind == "prefill":
                step, dn = make_prefill_step(cfg), ()
            else:
                step, dn = make_decode_step(cfg), (2,)
            compiled = jax.jit(step, in_shardings=in_sh,
                               donate_argnums=dn).lower(*specs).compile()
        finally:
            shctx.set_seq_axis(None)

    text = compiled.as_text()
    rows = []
    by_op = collections.Counter()
    for line in text.splitlines():
        m = re.match(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(",
                     line)
        if not m:
            continue
        name, type_str, op = m.groups()
        b = shape_bytes(type_str)
        if b:
            rows.append((b, op, name, type_str[:70]))
            by_op[op] += b
    rows.sort(reverse=True)
    print("== top ops by result bytes ==")
    for b, op, name, t in rows[: args.top]:
        print(f"{b/1e6:10.1f} MB  {op:<22} {name[:40]:<42} {t}")
    print("\n== total result bytes by op kind (top 15) ==")
    for op, b in by_op.most_common(15):
        print(f"{b/1e9:10.2f} GB  {op}")
    c = analysis.cost_dict(compiled)
    print(f"\ncost_analysis: flops={c.get('flops',0):.3e} "
          f"bytes={c.get('bytes accessed',0):.3e}")


if __name__ == "__main__":
    main()
