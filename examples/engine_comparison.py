"""Reproduce the paper's core claim at example scale: the DataStates engine
blocks training far less than the DeepSpeed-default / TorchSnapshot-style
baselines for the same checkpoint workload.

Runs the same training loop once per engine (sync, snapshot,
datastates-old, datastates), checkpointing every iteration, and prints a
Table-III-style comparison of blocking time, capture stall, and
end-to-end wall time. A storage-throughput throttle models a parallel
filesystem so the I/O-bound effects are visible at CPU-example scale.

    PYTHONPATH=src python examples/engine_comparison.py
"""

import dataclasses
import tempfile
import time

from repro.configs import get_config, uniform_groups
from repro.core import CheckpointManager, CheckpointPolicy, EnginePolicy
from repro.training.loop import Trainer


def small_model():
    base = get_config("llama3.2-1b")
    return dataclasses.replace(
        base, name="llama-20m", n_layers=4, d_model=384, n_heads=6,
        n_kv_heads=2, d_ff=1024, vocab=8_192,
        layer_groups=uniform_groups("full", 4))


def run_engine(mode: str, steps: int = 8):
    cfg = small_model()
    with tempfile.TemporaryDirectory() as d:
        # throttle flushes to ~300 MB/s to emulate a contended PFS share;
        # only the EnginePolicy differs between variants — policy objects
        # make that explicit (CheckpointManager.from_policy)
        mgr = CheckpointManager.from_policy(
            d, CheckpointPolicy(engine=EnginePolicy(
                mode=mode, host_cache_bytes=1 << 30, throttle_mbps=300.0)))
        tr = Trainer(cfg, batch=4, seq_len=128, manager=mgr)
        t0 = time.perf_counter()
        recs = tr.run(steps, ckpt_interval=1)
        mgr.wait_for_persist()
        wall = time.perf_counter() - t0
        futs = mgr._inflight
        blocking = sum(f.stats.blocking_s for f in futs)
        stall = sum(r.ckpt_stall_s for r in recs)
        ckpt_bytes = sum(f.stats.bytes_tensors + f.stats.bytes_objects
                         for f in futs)
        mgr.close()
    return {"wall_s": wall, "blocking_s": blocking, "stall_s": stall,
            "ckpt_gb": ckpt_bytes / 1e9, "steps": steps}


def main() -> int:
    print(f"{'engine':<16}{'wall(s)':>9}{'block(s)':>10}{'stall(s)':>10}"
          f"{'eff.tput(GB/s)':>16}")
    rows = {}
    for mode in ("sync", "snapshot", "datastates-old", "datastates"):
        r = run_engine(mode)
        rows[mode] = r
        blocked = r["blocking_s"] + r["stall_s"]
        tput = r["ckpt_gb"] / max(blocked, 1e-9)
        print(f"{mode:<16}{r['wall_s']:>9.2f}{r['blocking_s']:>10.3f}"
              f"{r['stall_s']:>10.3f}{tput:>16.2f}")
    speedup = rows["sync"]["wall_s"] / rows["datastates"]["wall_s"]
    print(f"\nDataStates end-to-end speedup vs DeepSpeed-default: "
          f"{speedup:.2f}x (paper reports 1.3–2.2x at cluster scale)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
