"""Serve a model from a training checkpoint: batched prefill + decode.

Trains briefly, checkpoints, then restores the parameters into a serving
engine and runs greedy generation over a batch of variable prompts —
the suspend/resume + deployment use-case from the paper's introduction.

    PYTHONPATH=src python examples/serve_restore.py
"""

import tempfile

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import CheckpointManager
from repro.serving.engine import greedy_generate, load_params_for_serving
from repro.training.loop import Trainer


def main() -> int:
    cfg = smoke_variant(get_config("starcoder2-7b"))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager.from_policy(d)
        tr = Trainer(cfg, batch=4, seq_len=64, manager=mgr)
        tr.run(4, ckpt_interval=4)
        mgr.wait_for_persist()
        mgr.close()
        print(f"trained {tr.step} steps, checkpoint persisted")

        # --- restore the *model only* into a serving process --------------
        # load_params_for_serving plans the shard intersections up front and
        # reads just the parameter byte ranges (no optimizer state) through
        # the parallel RestoreEngine.
        params, rstats = load_params_for_serving(d, tr.params)
        print(f"restored params: {rstats.bytes_read / 2**20:.1f} MiB read "
              f"in {rstats.n_ranges} ranges over {rstats.threads} threads "
              f"(index {rstats.index_s * 1e3:.1f} ms, read "
              f"{rstats.read_s * 1e3:.1f} ms, assemble "
              f"{rstats.assemble_s * 1e3:.1f} ms)")

        rng = np.random.default_rng(0)
        batch = 4
        prompts = jnp.asarray(
            rng.integers(1, cfg.vocab, size=(batch, 12)), jnp.int32)
        out = greedy_generate(cfg, params, {"tokens": prompts}, n_new=16)
        print(f"served batch of {batch} prompts → completions "
              f"{tuple(out.shape)}:")
        for i in range(batch):
            print(f"  req {i}: prompt={np.asarray(prompts[i])[:6]}... "
                  f"completion={np.asarray(out[i])[:8]}...")
        assert out.shape == (batch, 16)
        print("batched serve-from-checkpoint ✓")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
