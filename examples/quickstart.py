"""Quickstart: lazy asynchronous checkpointing in five minutes.

Trains a reduced llama3.2-1b on synthetic data with the DataStates engine
checkpointing every iteration, then restores into a fresh trainer and shows
the two runs continue identically.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import CheckpointManager
from repro.training.loop import Trainer


def main() -> int:
    cfg = smoke_variant(get_config("llama3.2-1b"))
    print(f"arch={cfg.name}  layers={cfg.n_layers}  d_model={cfg.d_model}")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        # --- train 6 steps, lazy-checkpoint every 2 -----------------------
        mgr = CheckpointManager(ckpt_dir, mode="datastates",
                                host_cache_bytes=256 << 20)
        trainer = Trainer(cfg, batch=4, seq_len=64, manager=mgr)
        records = trainer.run(6, ckpt_interval=2)
        for r in records:
            flag = " [ckpt]" if r.ckpt_requested else ""
            print(f"  step {r.step}: loss={r.loss:.4f} "
                  f"iter={r.iter_s*1e3:.0f}ms "
                  f"stall={r.ckpt_stall_s*1e6:.0f}us{flag}")

        # --- resume from the latest checkpoint ----------------------------
        resumed = Trainer(cfg, batch=4, seq_len=64, manager=mgr)
        step = resumed.resume()
        print(f"resumed at step {step}")
        cont_a = trainer.run(2)[-2:]
        cont_b = resumed.run(2)[-2:]
        # the resumed run replays the same trajectory bit-for-bit
        la = [r.loss for r in cont_a]
        lb = [r.loss for r in cont_b]
        print(f"  original  continues: {la}")
        print(f"  restored  continues: {lb}")
        np.testing.assert_allclose(la, lb, rtol=1e-6)
        print("restored trainer reproduces the original trajectory ✓")
        mgr.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
