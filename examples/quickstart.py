"""Quickstart: lazy asynchronous checkpointing in five minutes.

Trains a reduced llama3.2-1b on synthetic data with the DataStates engine
checkpointing every iteration, then restores into a fresh trainer and shows
the two runs continue identically.

The manager is configured the policy-first way (``CheckpointPolicy`` +
``CheckpointManager.from_policy``): one composable config object per
subsystem instead of a flat kwarg list, plus a ``StateProviderRegistry``
making the per-domain provider routing explicit — the paper's composable
state providers as the public API.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import (CheckpointManager, CheckpointPolicy, EnginePolicy,
                        StateProviderRegistry)
from repro.training.loop import Trainer


def main() -> int:
    cfg = smoke_variant(get_config("llama3.2-1b"))
    print(f"arch={cfg.name}  layers={cfg.n_layers}  d_model={cfg.d_model}")

    # Policy: engine tuning + per-domain provider routing. The registry's
    # rules match in order; here model tensors are pinned raw and the rest
    # takes the adaptive default ("auto": raw, or XOR-delta under a
    # DeltaPolicy). To trade optimizer bytes for bounded loss, add
    #   .add_rule(provider="quantized", domain="optimizer",
    #             dtype="float32")
    # ahead of the catch-all (benchmarks/fig_quantized.py measures it).
    policy = CheckpointPolicy(
        engine=EnginePolicy(mode="datastates", host_cache_bytes=256 << 20),
        providers=(StateProviderRegistry()
                   .add_rule(provider="tensor", domain="model")
                   .add_rule(provider="auto")))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        # --- train 6 steps, lazy-checkpoint every 2 -----------------------
        mgr = CheckpointManager.from_policy(ckpt_dir, policy)
        trainer = Trainer(cfg, batch=4, seq_len=64, manager=mgr)
        records = trainer.run(6, ckpt_interval=2)
        for r in records:
            flag = " [ckpt]" if r.ckpt_requested else ""
            print(f"  step {r.step}: loss={r.loss:.4f} "
                  f"iter={r.iter_s*1e3:.0f}ms "
                  f"stall={r.ckpt_stall_s*1e6:.0f}us{flag}")
        mgr.wait_for_commit()
        man = mgr.repository.manifest(mgr.latest_step())
        print("domain routing on disk:",
              {d: v["providers"] for d, v in man.meta["domains"].items()})

        # --- resume from the latest checkpoint ----------------------------
        resumed = Trainer(cfg, batch=4, seq_len=64, manager=mgr)
        step = resumed.resume()
        print(f"resumed at step {step}")
        cont_a = trainer.run(2)[-2:]
        cont_b = resumed.run(2)[-2:]
        # the resumed run replays the same trajectory bit-for-bit
        la = [r.loss for r in cont_a]
        lb = [r.loss for r in cont_b]
        print(f"  original  continues: {la}")
        print(f"  restored  continues: {lb}")
        np.testing.assert_allclose(la, lb, rtol=1e-6)

        # --- selective restore: model domain only -------------------------
        serving = Trainer(cfg, batch=4, seq_len=64, manager=mgr)
        serving.resume(domains=("model",))
        print(f"model-only resume read "
              f"{mgr.last_restore_stats.bytes_read/2**20:.1f} MiB "
              f"(optimizer bytes never touched)")
        print("restored trainer reproduces the original trajectory ✓")
        mgr.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
