"""Elastic resume: save on one mesh shape, restore onto another.

The checkpoint stores whatever shard boundaries the *training* layout
dictated (the planner never reshards, paper §IV-C). The parallel
RestoreEngine makes the reverse direction cheap: for each target shard of
the *new* mesh it intersects the stored shard regions up front and issues
ranged reads for just the overlapping bytes — so a 4×2 → 2×4 mesh change
(or a scale-up/scale-down after node failure) needs no offline reshard
pass.

    PYTHONPATH=src python examples/elastic_resume.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import CheckpointManager
from repro.launch.mesh import make_mesh


def main() -> int:
    # --- "training" run: a 4×2 data×model mesh --------------------------
    mesh_a = make_mesh((4, 2), ("data", "model"))
    w = jax.device_put(
        jnp.arange(256 * 128, dtype=jnp.float32).reshape(256, 128),
        NamedSharding(mesh_a, P("data", "model")))
    m = jax.device_put(jnp.ones((256, 128)),      # ZeRO-1-style: data only
                       NamedSharding(mesh_a, P("data", None)))
    state = {"model": {"w": w}, "optimizer": {"m": m},
             "meta": {"step": 12, "mesh": "4x2"}}

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager.from_policy(d)
        mgr.save(12, state, blocking=True)
        print(f"saved on mesh {mesh_a.devices.shape} "
              f"({len(jax.devices())} devices)")

        # --- "resume" run: the job comes back on a 2×4 mesh -------------
        mesh_b = make_mesh((2, 4), ("data", "model"))
        template = {
            "model": {"w": jax.ShapeDtypeStruct(
                (256, 128), jnp.float32,
                sharding=NamedSharding(mesh_b, P("model", "data")))},
            "optimizer": {"m": jax.ShapeDtypeStruct(
                (256, 128), jnp.float32,
                sharding=NamedSharding(mesh_b, P(None, "model")))},
            "meta": {"step": 0, "mesh": ""},
        }
        restored = mgr.restore(template, step=12)
        stats = mgr.last_restore_stats
        mgr.close()

        np.testing.assert_array_equal(np.asarray(restored["model"]["w"]),
                                      np.asarray(w))
        np.testing.assert_array_equal(np.asarray(restored["optimizer"]["m"]),
                                      np.asarray(m))
        assert restored["meta"]["step"] == 12
        shard_shapes = sorted({s.data.shape
                               for s in restored["model"]["w"].addressable_shards})
        print(f"restored onto mesh {mesh_b.devices.shape} with flipped "
              f"partition specs; per-device shard shape {shard_shapes}")
        print(f"restore stats: {stats.bytes_read / 2**20:.2f} MiB in "
              f"{stats.n_ranges} ranged reads across {stats.n_files} files "
              f"({stats.threads} threads; index {stats.index_s * 1e3:.1f} ms, "
              f"read {stats.read_s * 1e3:.1f} ms, assemble "
              f"{stats.assemble_s * 1e3:.1f} ms)")
        print("elastic resume across mesh shapes ✓")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
