"""End-to-end driver: train a ~100M-parameter llama-family model with
per-interval lazy checkpoints, crash after a while, and resume.

This is the "production" example: a real (not smoke-reduced) ~100M config,
a few hundred steps, checkpoint every N iterations with the DataStates
engine, then a simulated failure + restart that verifies the resumed
trajectory matches.

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--fast]

``--fast`` shrinks steps/sequence for CI-style runs (~1 min on CPU).
"""

import argparse
import dataclasses
import time

import numpy as np

from repro.configs import get_config, uniform_groups
from repro.core import CheckpointManager
from repro.optim.adamw import AdamWConfig
from repro.training.loop import Trainer


def make_100m_config():
    """A ~100M dense llama-family model (8L, d=768, 12H/4KV, ff=2048)."""
    base = get_config("llama3.2-1b")
    cfg = dataclasses.replace(
        base, name="llama-100m", n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=2048, vocab=32_000,
        layer_groups=uniform_groups("full", 8),
    )
    return cfg


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-interval", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    if args.fast:
        args.seq_len, args.batch, args.ckpt_interval = 64, 2, 3
        args.steps = min(args.steps, 12)

    cfg = make_100m_config()
    print(f"model: {cfg.name}  params≈{cfg.n_params()/1e6:.1f}M")

    # fresh run: this example demonstrates crash+resume WITHIN one run
    import shutil
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    from repro.core import CheckpointPolicy, EnginePolicy
    mgr = CheckpointManager.from_policy(
        args.ckpt_dir, CheckpointPolicy(engine=EnginePolicy(
            mode="datastates", host_cache_bytes=2 << 30)))
    tr = Trainer(cfg, batch=args.batch, seq_len=args.seq_len, manager=mgr,
                 hp=AdamWConfig(lr=3e-4))

    # ---- phase 1: train until a "failure" two thirds of the way in -------
    crash_at = (2 * args.steps // 3) // args.ckpt_interval * args.ckpt_interval
    t0 = time.perf_counter()
    recs = tr.run(crash_at, ckpt_interval=args.ckpt_interval)
    mgr.wait_for_persist()
    t1 = time.perf_counter()
    stalls = sum(r.ckpt_stall_s for r in recs)
    print(f"phase 1: {crash_at} steps in {t1-t0:.1f}s  "
          f"loss {recs[0].loss:.3f}→{recs[-1].loss:.3f}  "
          f"total ckpt stall {stalls*1e3:.1f}ms "
          f"({100*stalls/(t1-t0):.2f}% of wall)")
    ref_losses = [r.loss
                  for r in tr.run(args.steps - crash_at)[-(args.steps - crash_at):]]
    print(f"(reference continuation to step {args.steps} recorded)")

    # ---- phase 2: "crash" — new process state, resume from latest --------
    tr2 = Trainer(cfg, batch=args.batch, seq_len=args.seq_len, manager=mgr,
                  hp=AdamWConfig(lr=3e-4))
    step = tr2.resume()
    print(f"phase 2: resumed from step {step}")
    recs2 = tr2.run(args.steps - step, ckpt_interval=args.ckpt_interval)
    got_losses = [r.loss for r in recs2]
    np.testing.assert_allclose(got_losses, ref_losses, rtol=1e-5, atol=1e-5)
    print(f"resumed trajectory matches uninterrupted run over "
          f"{len(got_losses)} steps ✓  (final loss {got_losses[-1]:.3f})")
    mgr.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
