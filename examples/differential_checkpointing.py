"""Differential checkpointing on the main engine path (paper §VII).

The paper's future-work section proposes data reduction (differential
checkpointing, compression) to lower storage cost at high checkpoint
rates. This example exercises the first-class implementation:
``CheckpointManager(..., delta=DeltaPolicy(keyframe_every=K))`` streams
XOR deltas (Pallas kernel) of each tensor against a retained previous
snapshot through the async data-movement engine, compresses them on the
flush lanes, and commits them to the chain-aware catalog; restore replays
keyframe ⊕ deltas through the parallel RestoreEngine — bit-exactly.

(The old standalone ``DifferentialCheckpointer`` sidecar is deprecated
for training use; this is the engine path that replaces it.)

    PYTHONPATH=src python examples/differential_checkpointing.py
"""

import tempfile

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import CheckpointManager, CheckpointPolicy, DeltaPolicy
from repro.training.loop import Trainer


def main() -> int:
    cfg = smoke_variant(get_config("llama3.2-1b"))

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager.from_policy(
            d, CheckpointPolicy(delta=DeltaPolicy(keyframe_every=4)))
        tr = Trainer(cfg, batch=2, seq_len=64, manager=mgr)
        for step in range(1, 7):
            tr.run(1)
            mgr.save(tr.step, tr.state(), blocking=True)
            m = mgr.repository.manifest(tr.step)
            meta = m.meta["delta"]
            kind = "keyframe" if meta["keyframe"] else \
                f"delta→{meta['base_step']}"
            print(f"  step {tr.step}: {kind:10s} "
                  f"{m.total_bytes/1e6:7.3f} MB on disk "
                  f"(chain depth {meta['chain_depth']})")

        # restore the last step (a delta) and verify bit-exactness: the
        # manager walks the chain, re-verifies every member's checksums,
        # restores the keyframe, and folds the deltas in
        restored = mgr.restore(tr.state(), step=tr.step)
        for (pa, a), (_pb, b) in zip(
                jax.tree_util.tree_flatten_with_path(tr.params)[0],
                jax.tree_util.tree_flatten_with_path(restored["model"])[0]):
            np.testing.assert_array_equal(
                np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8))
        print("differential chain restore is bit-exact ✓")

        steps = mgr.repository.steps()
        key_mb = np.mean([mgr.repository.manifest(s).total_bytes
                          for s in steps
                          if mgr.repository.manifest(s)
                          .meta["delta"]["keyframe"]]) / 1e6
        del_mb = np.mean([mgr.repository.manifest(s).total_bytes
                          for s in steps
                          if not mgr.repository.manifest(s)
                          .meta["delta"]["keyframe"]]) / 1e6
        print(f"mean keyframe {key_mb:.3f} MB vs mean delta {del_mb:.3f} MB "
              f"→ {key_mb/max(del_mb, 1e-9):.1f}x smaller increments")

        # chain-aware retention: keep-last-1 still keeps the keyframe the
        # newest delta depends on
        from repro.storage.repository import RetentionPolicy
        mgr.repository.gc(retention=RetentionPolicy(keep_last_n=1))
        print(f"after keep-last-1 GC the chain survives: "
              f"{mgr.repository.local_steps()}")
        mgr.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
