"""Beyond-paper feature: differential + quantized checkpointing.

The paper's future-work section proposes data reduction (differential
checkpointing, compression) to lower storage cost at high checkpoint
rates. This example exercises our implementation: device-side delta
encoding (Pallas kernel, validated in interpret mode) against the previous
snapshot, zstd compression, and optional int8/bf16 quantization — then
shows the storage savings for a slowly-changing optimizer state.

    PYTHONPATH=src python examples/differential_checkpointing.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.reduction import DifferentialCheckpointer
from repro.training.loop import Trainer


def main() -> int:
    cfg = smoke_variant(get_config("llama3.2-1b"))
    tr = Trainer(cfg, batch=2, seq_len=64)

    with tempfile.TemporaryDirectory() as d:
        diff = DifferentialCheckpointer(d, keyframe_every=4)
        sizes = []
        for step in range(1, 7):
            tr.run(1)
            info = diff.save(step, tr.params)
            sizes.append(info)
            kind = "keyframe" if info["keyframe"] else "delta   "
            print(f"  step {step}: {kind} {info['compressed_bytes']/1e6:7.3f} MB "
                  f"(raw {info['raw_bytes']/1e6:.3f} MB, "
                  f"ratio {info['ratio']:.1f}x)")

        # restore the last step and verify bit-exactness
        restored = diff.restore(6)
        leaves, _ = jax.tree_util.tree_flatten_with_path(tr.params)
        for path, leaf in leaves:
            k = jax.tree_util.keystr(path)
            a = np.asarray(leaf).view(np.uint8)
            b = restored[k].view(np.uint8)
            np.testing.assert_array_equal(a, b)
        print("differential restore is bit-exact across keyframe+deltas ✓")

        key_mb = np.mean([s["compressed_bytes"] for s in sizes if s["keyframe"]]) / 1e6
        del_mb = np.mean([s["compressed_bytes"] for s in sizes if not s["keyframe"]]) / 1e6
        print(f"mean keyframe {key_mb:.3f} MB vs mean delta {del_mb:.3f} MB "
              f"→ {key_mb/max(del_mb,1e-9):.1f}x smaller increments")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
