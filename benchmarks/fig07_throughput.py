"""Fig 7: effective checkpoint throughput vs model size, per engine.

Effective throughput = checkpoint bytes / time training is *blocked*
(save prologue + capture barrier before the next update) — the paper's
application-facing metric. Trained for several iterations checkpointing
every iteration, like the paper's stress setup.
"""

from __future__ import annotations

from typing import List

from .common import (ENGINE_ORDER, TempDir, bench_cfg, make_trainer,
                     manager_for, save_results, state_nbytes)


def run(quick: bool = False) -> List[dict]:
    scales = [(2, 256), (2, 512)] if quick else [(2, 256), (2, 512), (4, 768)]
    iters = 4 if quick else 8
    rows = []
    for n_layers, d in scales:
        cfg = bench_cfg(n_layers, d)
        for mode in ENGINE_ORDER:
            with TempDir() as ckpt_dir:
                mgr = manager_for(mode, ckpt_dir)
                tr = make_trainer(cfg, mgr)
                nbytes = state_nbytes(tr.state())
                recs = tr.run(iters, ckpt_interval=1)
                mgr.drain()
                blocked = sum(r.ckpt_stall_s for r in recs
                              if r.ckpt_requested or r.ckpt_stall_s > 0)
                n_ckpts = sum(1 for r in recs if r.ckpt_requested)
                mgr.close()
            thpt = (nbytes * n_ckpts) / max(blocked, 1e-9)
            rows.append({"model": cfg.name, "state_mb": nbytes / 2**20,
                         "engine": mode, "n_ckpts": n_ckpts,
                         "blocked_s": blocked,
                         "effective_gbps": thpt / 1e9})
    save_results("fig07_throughput", rows)
    return rows


def summarize(rows) -> List[str]:
    out = []
    for r in rows:
        out.append(f"fig07/{r['model']}/{r['engine']},"
                   f"{r['blocked_s']*1e6/max(r['n_ckpts'],1):.0f},"
                   f"eff_thpt={r['effective_gbps']:.2f}GB/s")
    return out
