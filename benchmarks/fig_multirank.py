"""Fig (multirank): aggregate save throughput scales with writer ranks.

The paper's §VI evaluation is multi-writer — every rank drains its own
shards concurrently, and the headline 4× gain needs all ranks' I/O lanes
running at once. The seed pipeline funneled every byte through a single
``DataMovementEngine``; the multi-rank coordinator gives each simulated
rank its own engine + host-cache lane and a balanced partition of the
shards.

Methodology: one fixed heterogeneous state (numpy payload — pure I/O, no
D2H jitter), one *per-lane* write throttle emulating a PFS stream exactly
like every other benchmark (``flush_threads=1`` per writer, so the lane —
not local SSD burst — is the binding constraint). The single-writer
variant is the seed path: one engine, one lane. ``world=N`` runs the
coordinator: N lanes, two-phase commit included in the measured persist
latency (rank manifests + ack collective; checksums off on both sides so
the comparison is movement, not hashing).

Acceptance (ISSUE 3): ≥2× aggregate throughput at 4 simulated ranks vs
the single-writer path on the same state, and no replicated shard written
twice (every tensor appears in exactly one rank file).

ISSUE 8 adds a process-runtime variant: the same 4-rank save with every
writer a spawned OS process (``runtime="process"``, two nodes of two
ranks, hierarchical commit). Its acceptance is functional, not a speedup
bar — the payload crosses a real pipe, so IPC serialization rides the
measured persist — the row must commit with the full node-manifest tree
and pass the same dedup audit.
"""

from __future__ import annotations

import glob
import os
import time
from typing import List

import numpy as np

from repro.core import (CheckpointManager, CheckpointPolicy,
                        DistPolicy, EnginePolicy, FileReader,
                        StoragePolicy)

from .common import TempDir, save_results

LANE_MBPS = 300.0        # emulated per-writer-lane storage bandwidth
WORLDS = (1, 2, 4)


def _payload(total_mib: int) -> dict:
    """~total_mib of heterogeneous numpy tensors + a little object state."""
    rng = np.random.default_rng(0)
    n_arrays = 24
    per = total_mib * (1 << 20) // n_arrays // 4
    model = {f"layer{i:02d}": rng.standard_normal(per).astype(np.float32)
             for i in range(n_arrays)}
    return {"model": model, "meta": {"step": 0, "note": "fig_multirank"}}


def _payload_nbytes(state) -> int:
    return sum(v.nbytes for v in state["model"].values())


def _dedup_audit(directory: str, step: int) -> dict:
    """Every tensor in exactly one rank file; bytes stored ≈ payload."""
    files = sorted(glob.glob(
        os.path.join(directory, f"global_step{step}", "*.dsllm")))
    names: List[str] = []
    tensor_bytes = 0
    for f in files:
        rd = FileReader(f)
        for entry in rd.tensors.values():
            names.append(entry.name)
            tensor_bytes += entry.nbytes
    return {"n_files": len(files), "n_tensors": len(names),
            "unique": len(names) == len(set(names)),
            "tensor_bytes": tensor_bytes}


def _run_variant(world: int, state, repeats: int,
                 runtime: str = "thread") -> dict:
    nbytes = _payload_nbytes(state)
    with TempDir() as d:
        coordinator = None
        if world > 1:
            # built by hand so the per-WRITER resources are explicit: one
            # flush lane and one host-cache slice per rank, same per-lane
            # throttle as the single-writer baseline (the manager-level
            # `world=` would divide node totals instead)
            from repro.dist import Coordinator
            coordinator = Coordinator(
                world, mode="datastates", runtime=runtime,
                node_size=2 if runtime == "process" else None,
                host_cache_bytes=(64 << 20) // world, flush_threads=1,
                throttle_mbps=LANE_MBPS, checksum_files=False)
        mgr = CheckpointManager.from_policy(
            d, CheckpointPolicy(
                engine=EnginePolicy(host_cache_bytes=64 << 20,
                                    flush_threads=1,
                                    throttle_mbps=LANE_MBPS),
                storage=StoragePolicy(manifest_checksums=False),
                dist=DistPolicy(coordinator=coordinator)))
        best = None
        for rep in range(repeats):
            step = rep + 1
            t0 = time.perf_counter()
            fut = mgr.save(step, state)
            fut.wait_persisted()
            persist_s = time.perf_counter() - t0
            if best is None or persist_s < best:
                best = persist_s
            mgr.wait_for_commit(step)
        audit = _dedup_audit(d, repeats)
        if runtime == "process":
            from repro.storage.manifest import read_node_manifests
            sdir = os.path.join(d, f"global_step{repeats}")
            audit["n_nodes"] = len(read_node_manifests(sdir))
        mgr.close()
    if world == 1:
        variant = "single-writer"
    else:
        variant = f"world-{world}" + ("-proc" if runtime == "process"
                                      else "")
    return {
        "variant": variant, "world": world, "runtime": runtime,
        "ckpt_bytes": nbytes,
        "persist_s": best,
        "throughput_mbps": nbytes / best / 1e6,
        "lane_mbps": LANE_MBPS,
        **{f"audit_{k}": v for k, v in audit.items()},
    }


def run(quick: bool = False) -> List[dict]:
    state = _payload(48 if quick else 128)
    repeats = 2 if quick else 3
    rows = [_run_variant(w, state, repeats) for w in WORLDS]
    rows.append(_run_variant(4, state, repeats, runtime="process"))
    base = rows[0]["throughput_mbps"]
    for r in rows:
        r["speedup_vs_single"] = r["throughput_mbps"] / base
    save_results("fig_multirank", rows,
                 meta={"lane_mbps": LANE_MBPS,
                       "note": "flush_threads=1 per writer; per-lane "
                               "throttle is the binding constraint"})
    return rows


def summarize(rows) -> List[str]:
    lines = []
    for r in rows:
        ok = "dedup_ok" if r["audit_unique"] else "DEDUP-VIOLATED"
        lines.append(
            f"fig_multirank/{r['variant']},{r['persist_s'] * 1e6:.0f},"
            f"throughput={r['throughput_mbps']:.0f}MB/s "
            f"speedup={r['speedup_vs_single']:.2f}x "
            f"files={r['audit_n_files']} {ok}")
    w4 = next((r for r in rows if r["world"] == 4
               and r.get("runtime", "thread") == "thread"), None)
    if w4 is not None:
        verdict = "PASS" if w4["speedup_vs_single"] >= 2.0 \
            and w4["audit_unique"] else "FAIL"
        lines.append(
            f"fig_multirank/acceptance,0,"
            f"4-rank_speedup={w4['speedup_vs_single']:.2f}x (>=2x) "
            f"{verdict}")
    proc = next((r for r in rows if r.get("runtime") == "process"), None)
    if proc is not None:
        # functional acceptance: real-process save committed through the
        # full hierarchical tree (2 nodes of 2 ranks) and deduped
        ok = proc["audit_unique"] and proc.get("audit_n_nodes") == 2
        lines.append(
            f"fig_multirank/proc-acceptance,0,"
            f"process-runtime_commit nodes={proc.get('audit_n_nodes')} "
            f"throughput={proc['throughput_mbps']:.0f}MB/s "
            f"{'PASS' if ok else 'FAIL'}")
    return lines
