"""Fig 14: node-level flush throughput vs payload size, per engine, plus an
"ideal" host-only pwrite baseline (the peak-capability reference line)."""

from __future__ import annotations

import os
import time
from typing import List

import jax.numpy as jnp
import numpy as np

from .common import (ENGINE_ORDER, TempDir, manager_for, save_results,
                     THROTTLE_MBPS)


def run(quick: bool = False) -> List[dict]:
    sizes_mb = [8, 32] if quick else [8, 32, 128]
    rows = []
    for mb in sizes_mb:
        n = mb * (1 << 20) // 4
        state = {"model": {"t": jnp.arange(n, dtype=jnp.float32)},
                 "meta": {"step": 0}}
        # ideal: host->file writes of an existing host buffer from 4
        # parallel writers (the paper's 4 ranks/node microbench), 4 MiB
        # chunks at the same per-thread throttle the engines' flush threads
        # see — the peak-capability line (no staging, no serialization).
        host = np.arange(n, dtype=np.float32)
        chunk = 4 << 20
        n_writers = 4
        with TempDir() as d:
            import threading

            def writer(widx: int) -> None:
                lo = widx * host.nbytes // n_writers
                hi = (widx + 1) * host.nbytes // n_writers
                fd = os.open(os.path.join(d, f"ideal{widx}.bin"),
                             os.O_WRONLY | os.O_CREAT | os.O_TRUNC)
                view = memoryview(host).cast("B")
                for off in range(lo, hi, chunk):
                    t_c = time.perf_counter()
                    end = min(off + chunk, hi)
                    os.pwrite(fd, view[off:end], off - lo)
                    left = (end - off) / (THROTTLE_MBPS * 1e6) \
                        - (time.perf_counter() - t_c)
                    if left > 0:
                        time.sleep(left)
                os.close(fd)

            t0 = time.perf_counter()
            ts = [threading.Thread(target=writer, args=(i,))
                  for i in range(n_writers)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            ideal = host.nbytes / (time.perf_counter() - t0)
        rows.append({"size_mb": mb, "engine": "ideal-host-only",
                     "gbps": ideal / 1e9})
        for mode in ENGINE_ORDER:
            with TempDir() as d:
                mgr = manager_for(mode, d, cache_mb=max(2 * mb, 64))
                t0 = time.perf_counter()
                fut = mgr.save(0, state)
                fut.wait_persisted()
                dt = time.perf_counter() - t0
                mgr.close()
            rows.append({"size_mb": mb, "engine": mode,
                         "gbps": fut.stats.total_bytes / dt / 1e9})
    save_results("fig14_flush", rows, meta={"throttle_mbps": THROTTLE_MBPS})
    return rows


def summarize(rows) -> List[str]:
    return [f"fig14/{r['size_mb']}MB/{r['engine']},0,"
            f"{r['gbps']:.2f}GB/s" for r in rows]
