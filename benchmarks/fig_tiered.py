"""Fig (tiered): cascade-to-remote overlap cost + retention GC bound.

Two claims for the tiered checkpoint repository:

1. **Cascade overlap** — replicating every committed step to a
   bandwidth-throttled remote tier (simulated object store, multipart
   upload) in the background adds <10% iteration-time overhead vs
   local-only checkpointing at the same checkpoint frequency: the cascade
   rides the repository's background lanes exactly like the engine's flush
   rides the training compute (TierCheck's thesis on top of the paper's).
2. **Bounded footprint** — with a keep-last-N retention policy, ≥3·N saves
   keep the local tier's on-disk footprint bounded near N+1 steps' worth
   of bytes (the +1 is the just-committed step before GC turns over),
   while pinned steps survive; GC cost per invocation is reported.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (CheckpointManager, CheckpointPolicy,
                        EnginePolicy, StoragePolicy)
from repro.storage import ObjectStoreBackend, RetentionPolicy, Tier

from .common import (THROTTLE_MBPS, TempDir, bench_cfg, make_trainer,
                     save_results, state_nbytes)

REMOTE_LATENCY_S = 0.002
REMOTE_BANDWIDTH_MBPS = 250.0


def _train_variant(cfg, n_steps: int, ckpt_interval: int, warmup: int,
                   tiers) -> dict:
    with TempDir() as d:
        remote = None
        if tiers:
            remote = ObjectStoreBackend(latency_s=REMOTE_LATENCY_S,
                                        bandwidth_mbps=REMOTE_BANDWIDTH_MBPS)
        mgr = CheckpointManager.from_policy(
            d, CheckpointPolicy(
                engine=EnginePolicy(host_cache_bytes=1536 << 20,
                                    throttle_mbps=THROTTLE_MBPS),
                storage=StoragePolicy(
                    tiers=(Tier("object", remote),) if remote else ())))
        tr = make_trainer(cfg, mgr)
        tr.run(warmup, ckpt_interval=0)  # jit compile outside the window
        t0 = time.perf_counter()
        records = tr.run(n_steps, ckpt_interval=ckpt_interval)
        train_wall = time.perf_counter() - t0
        repo = mgr.repository
        mgr.wait_for_commit()
        t_gc = time.perf_counter()
        repo.wait_cascaded()
        cascade_tail_s = time.perf_counter() - t_gc
        timed = records[-n_steps:]  # this run only (run() accumulates)
        iters = [r.iter_s for r in timed]
        row = {
            "variant": "cascade" if tiers else "local-only",
            "n_steps": n_steps, "ckpt_interval": ckpt_interval,
            "ckpt_bytes": state_nbytes(tr.state()),
            "mean_iter_s": float(np.mean(iters)),
            "p50_iter_s": float(np.median(iters)),
            "mean_stall_s": float(np.mean([r.ckpt_stall_s for r in timed])),
            "train_wall_s": train_wall,
            "cascade_tail_s": cascade_tail_s,  # left over after training
            "cascade_busy_s": sum(e.seconds for e in repo.cascade_log),
            "cascade_bytes": sum(e.nbytes for e in repo.cascade_log),
            "cascade_errors": len(repo.cascade_errors),
            "n_cascaded_steps": len({e.step for e in repo.cascade_log}),
        }
        if remote is not None:
            row["remote_requests"] = remote.stats["n_requests"]
            row["remote_multipart"] = remote.stats["n_multipart"]
        mgr.close()
        return row


def _gc_bound(cfg, keep_last: int, n_saves: int) -> dict:
    with TempDir() as d:
        mgr = CheckpointManager.from_policy(
            d, CheckpointPolicy(
                engine=EnginePolicy(host_cache_bytes=1536 << 20,
                                    throttle_mbps=THROTTLE_MBPS),
                storage=StoragePolicy(
                    retention=RetentionPolicy(keep_last_n=keep_last))))
        tr = make_trainer(cfg, mgr)
        state = tr.state()
        per_step = state_nbytes(state)
        footprints = []
        for s in range(1, n_saves + 1):
            mgr.save(s, state, blocking=True)
            footprints.append(mgr.repository.local_footprint_bytes())
        gc_times = [g.seconds for g in mgr.repository.gc_log]
        row = {
            "variant": f"gc-keep-last-{keep_last}",
            "n_saves": n_saves, "keep_last": keep_last,
            "ckpt_bytes": per_step,
            "max_footprint_bytes": max(footprints),
            "final_footprint_bytes": footprints[-1],
            "footprint_over_step": max(footprints) / per_step,
            "steps_on_disk": len(mgr.repository.local_steps()),
            "n_gc": len(gc_times),
            "mean_gc_s": float(np.mean(gc_times)) if gc_times else 0.0,
            "max_gc_s": float(max(gc_times)) if gc_times else 0.0,
        }
        mgr.close()
        return row


def run(quick: bool = False) -> List[dict]:
    cfg = bench_cfg(n_layers=2, d_model=192)
    n_steps = 12 if quick else 24
    # Checkpoint cadence the throttled remote can sustain (its bandwidth
    # bounds cascade drain; producing faster than the remote drains would
    # measure backlog, not overlap).
    interval = 4
    warmup = 2
    repeats = 1 if quick else 2
    # best-of-N per variant: this box has 2 cores, so scheduler noise
    # between separate training runs easily exceeds the effect measured.
    rows = []
    for tiers in (False, True):
        best = None
        for _ in range(repeats):
            r = _train_variant(cfg, n_steps, interval, warmup, tiers=tiers)
            if best is None or r["mean_iter_s"] < best["mean_iter_s"]:
                best = r
        rows.append(best)
    rows.append(_gc_bound(cfg, keep_last=2, n_saves=7 if quick else 10))
    save_results("fig_tiered", rows,
                 meta={"remote_latency_s": REMOTE_LATENCY_S,
                       "remote_bandwidth_mbps": REMOTE_BANDWIDTH_MBPS})
    return rows


def summarize(rows) -> List[str]:
    by = {r["variant"]: r for r in rows}
    lines = []
    local, casc = by.get("local-only"), by.get("cascade")
    if local and casc:
        overhead = (casc["mean_iter_s"] - local["mean_iter_s"]) \
            / local["mean_iter_s"]
        overlap = 0.0
        if casc["cascade_busy_s"]:
            overlap = 1.0 - casc["cascade_tail_s"] \
                / max(casc["cascade_busy_s"], 1e-9)
        lines.append(
            f"fig_tiered/overlap,{casc['mean_iter_s'] * 1e6:.0f},"
            f"local={local['mean_iter_s'] * 1e3:.1f}ms "
            f"cascade={casc['mean_iter_s'] * 1e3:.1f}ms "
            f"overhead={overhead * 100:+.1f}% "
            f"cascaded={casc['n_cascaded_steps']}steps/"
            f"{casc['cascade_bytes'] / 2 ** 20:.0f}MiB "
            f"overlapped={overlap * 100:.0f}%")
    gc = next((r for r in rows if r["variant"].startswith("gc-")), None)
    if gc:
        lines.append(
            f"fig_tiered/gc,{gc['mean_gc_s'] * 1e6:.0f},"
            f"keep_last={gc['keep_last']} saves={gc['n_saves']} "
            f"max_footprint={gc['footprint_over_step']:.2f}x_step "
            f"steps_on_disk={gc['steps_on_disk']} "
            f"gc_mean={gc['mean_gc_s'] * 1e3:.1f}ms")
    return lines
