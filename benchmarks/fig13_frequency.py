"""Fig 13: end-to-end time vs checkpoint interval (I/O pressure sweep).

The paper's claim: DataStates sustains ~5x more frequent checkpoints for the
same overhead as the best baseline.
"""

from __future__ import annotations

import time
from typing import List

from .common import (TempDir, bench_cfg, make_trainer, manager_for,
                     save_results)


def run(quick: bool = False) -> List[dict]:
    cfg = bench_cfg(2, 512)
    iters = 8 if quick else 20
    intervals = [1, 2] if quick else [1, 2, 5, 10]
    rows = []
    for mode in ("snapshot", "datastates"):
        for interval in intervals:
            with TempDir() as d:
                mgr = manager_for(mode, d)
                tr = make_trainer(cfg, mgr)
                t0 = time.perf_counter()
                tr.run(iters, ckpt_interval=interval)
                mgr.wait_for_persist()
                e2e = time.perf_counter() - t0
                mgr.close()
            rows.append({"engine": mode, "interval": interval,
                         "iters": iters, "e2e_s": e2e})
    save_results("fig13_frequency", rows)
    return rows


def summarize(rows) -> List[str]:
    return [f"fig13/interval{r['interval']}/{r['engine']},"
            f"{r['e2e_s']*1e6:.0f},e2e={r['e2e_s']:.2f}s" for r in rows]
