"""Fig (fleet warm-start): remote-tier bytes + p99 replica-ready time.

A new model lands on the object-store tier and R serving replicas must
warm-start *now*. The naive shape — every replica issues its own full
tier read — multiplies remote-tier egress by R and serializes on the
store's shared pipe. The fleet fabric (``repro.fleet``) collapses it:

* small objects ride the shared read-through :class:`FleetCache`
  (single-flight: R concurrent misses → one remote read);
* large shard files are assembled through :class:`PeerExchange` — each
  replica reads a disjoint ranged slice set (the restore planner's
  ``plan_ranged_slices``) and swaps for the rest, bittorrent-style;
* a fleet already holding step *k* pulls only the delta chain to the
  new step, never a fresh keyframe (``fleet.delta_pull``).

Scenarios, against one bandwidth-throttled shared-pipe
:class:`ObjectStoreBackend` (each replica gets its own local tier, so
every byte a replica ends up with was moved by remote read, peer
exchange, or cache hit — nothing is shared through the filesystem):

* ``cold``  × R ∈ {1, 8, 64} × {naive, fleet} — empty replicas restore
  the keyframe step through ``load_params_for_serving``; measured:
  remote ``bytes_out`` amplification (vs one checkpoint's bytes) and
  p99 replica-ready time.
* ``delta`` × R = 8 (fleet) — replicas already hold step 1 locally and
  warm to the delta step 2; measured: remote bytes vs the delta step's
  bytes (the chain bound) and vs the keyframe's bytes.

``--check`` gates against ``benchmarks/baselines/
fig_fleet_warmstart_baseline.json``: fleet amplification at R=64 stays
≤ ~1.2× one checkpoint (naive measures ≈ R×), fleet p99 beats naive
p99, and the delta pull moves only chain bytes. Every replica
byte-compares its restored parameters, so a corrupt exchange can never
pass as a win.

    PYTHONPATH=src python -m benchmarks.run --quick --only fig_fleet_warmstart
    PYTHONPATH=src python -m benchmarks.fig_fleet_warmstart --quick --check
"""

from __future__ import annotations

import argparse
import json
import math
import os
import shutil
import sys
import threading
import time
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import (CheckpointManager, CheckpointPolicy, DeltaPolicy,
                        EnginePolicy, StoragePolicy)
from repro.fleet import FleetFabric
from repro.serving.engine import load_params_for_serving
from repro.storage import CheckpointRepository, ObjectStoreBackend, Tier

from .common import RESULTS_DIR, TempDir, save_results

REPLICAS = (1, 8, 64)
N_TENSORS = 4
SHAPE = (1024, 1024)          # 4 × 4 MiB fp32 = 16 MiB checkpoint
SHAPE_QUICK = (512, 256)      # 4 × 512 KiB = 2 MiB (CI smoke)
SLICE_BYTES = 256 << 10       # peer-exchange slice (quick: 128 KiB)
SLICE_BYTES_QUICK = 128 << 10
REMOTE_LATENCY_S = 0.002
REMOTE_BANDWIDTH_MBPS = 400.0  # shared pipe: naive R=64 pays ~R× this
KEYFRAME_EVERY = 4             # save 1 = keyframe, save 2 = delta
MUTATE_ROWS = 101              # ~1% of rows move between saves
BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "fig_fleet_warmstart_baseline.json")


def _initial_state(shape) -> Dict:
    rng = np.random.default_rng(7)
    model = {f"w{i:02d}": jnp.asarray(
        rng.standard_normal(shape).astype(np.float32))
        for i in range(N_TENSORS)}
    return {"model": model, "meta": {"step": 0, "note": "fleet"}}


def _mutate(state, step: int) -> Dict:
    model = {k: v.at[::MUTATE_ROWS].add(np.float32(1e-3))
             for k, v in state["model"].items()}
    return {"model": model, "meta": {"step": step, "note": "fleet"}}


def _expected(state) -> Dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in state["model"].items()}


def _p99(times: List[float]) -> float:
    s = sorted(times)
    return s[max(0, math.ceil(0.99 * len(s)) - 1)]


def _publish(d: str, remote: ObjectStoreBackend, shape,
             step1_copy: str) -> Dict:
    """Train-side: commit keyframe step 1 + delta step 2, cascade both to
    the remote tier, and snapshot the local dir at step 1 (the delta
    scenario's 'fleet already on step k' starting point)."""
    state = _initial_state(shape)
    payload = sum(v.nbytes for v in state["model"].values())
    mgr = CheckpointManager.from_policy(
        d, CheckpointPolicy(
            engine=EnginePolicy(host_cache_bytes=payload * 3 + (64 << 20),
                                flush_threads=2),
            storage=StoragePolicy(tiers=(Tier("object", remote),)),
            delta=DeltaPolicy(keyframe_every=KEYFRAME_EVERY)))
    state = _mutate(state, 1)
    mgr.save(1, state, blocking=True)
    mgr.wait_for_commit(1)
    mgr.repository.wait_cascaded()
    shutil.copytree(d, step1_copy)  # quiescent: step 1 committed+cascaded
    expected1 = _expected(state)
    state = _mutate(state, 2)
    mgr.save(2, state, blocking=True)
    mgr.wait_for_commit(2)
    mgr.repository.wait_cascaded()
    out = {
        "expected1": expected1, "expected2": _expected(state),
        "keyframe_bytes": mgr.repository.manifest(1).total_bytes,
        "delta_bytes": mgr.repository.manifest(2).total_bytes,
    }
    mgr.close()
    return out


def _fan_out(remote: ObjectStoreBackend, replicas: int, step: int,
             expected: Dict[str, np.ndarray], fabric: Optional[FleetFabric],
             seed_dir: Optional[str] = None) -> dict:
    """R replica threads, each with its own local tier, restoring
    ``step`` via ``load_params_for_serving`` — through ``fabric`` when
    given, direct per-replica tier reads otherwise. Every replica
    byte-compares the restored parameters against the training state."""
    b0, r0 = remote.stats["bytes_out"], remote.stats["n_requests"]
    times: List[Optional[float]] = [None] * replicas
    errors: List[BaseException] = []
    barrier = threading.Barrier(replicas)
    with TempDir() as d:
        def replica(i: int) -> None:
            try:
                rdir = os.path.join(d, f"replica{i:03d}")
                if seed_dir is not None:
                    shutil.copytree(seed_dir, rdir)
                repo = CheckpointRepository(
                    rdir, remote_tiers=[Tier("object", remote)],
                    auto_cascade=False, auto_gc=False)
                tpl = {k: np.empty(v.shape, v.dtype)
                       for k, v in expected.items()}
                barrier.wait()
                t0 = time.perf_counter()
                params, _stats = load_params_for_serving(
                    rdir, tpl, step=step, threads=1, repository=repo,
                    fleet=fabric)
                times[i] = time.perf_counter() - t0
                for k, v in expected.items():
                    if not np.array_equal(np.asarray(params[k]), v):
                        raise AssertionError(
                            f"replica {i}: restored {k!r} differs from "
                            f"the training state")
                repo.close()
            except BaseException as exc:  # noqa: BLE001 — re-raised below
                errors.append(exc)
                try:
                    barrier.abort()
                except Exception:  # noqa: BLE001
                    pass

        t_wall = time.perf_counter()
        threads = [threading.Thread(target=replica, args=(i,), daemon=True)
                   for i in range(replicas)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
    ready = [t for t in times if t is not None]
    peer = 0
    if fabric is not None:
        st = fabric.step_stats().get(step, {})
        peer = int(st.get("peer_bytes", 0))
    return {
        "remote_bytes": remote.stats["bytes_out"] - b0,
        "remote_requests": remote.stats["n_requests"] - r0,
        "peer_bytes": peer,
        "ready_p99_s": _p99(ready),
        "ready_mean_s": float(np.mean(ready)),
        "wall_s": time.perf_counter() - t_wall,
    }


def run(quick: bool = False) -> List[dict]:
    shape = SHAPE_QUICK if quick else SHAPE
    slice_bytes = SLICE_BYTES_QUICK if quick else SLICE_BYTES
    remote = ObjectStoreBackend(latency_s=REMOTE_LATENCY_S,
                                bandwidth_mbps=REMOTE_BANDWIDTH_MBPS)
    rows: List[dict] = []
    with TempDir() as pub:
        d = os.path.join(pub, "train")
        step1_copy = os.path.join(pub, "fleet-at-step1")
        info = _publish(d, remote, shape, step1_copy)
        kf_bytes, delta_bytes = info["keyframe_bytes"], info["delta_bytes"]
        for mode in ("naive", "fleet"):
            for r in REPLICAS:
                fabric = FleetFabric(slice_bytes=slice_bytes) \
                    if mode == "fleet" else None  # cold cache per scenario
                m = _fan_out(remote, r, 1, info["expected1"], fabric)
                rows.append({
                    "scenario": "cold", "mode": mode, "replicas": r,
                    "ckpt_bytes": kf_bytes,
                    "amplification": m["remote_bytes"] / kf_bytes,
                    **m,
                })
        # fleet on step 1 warms to the delta step 2: chain bytes only
        fabric = FleetFabric(slice_bytes=slice_bytes)
        m = _fan_out(remote, 8, 2, info["expected2"], fabric,
                     seed_dir=step1_copy)
        rows.append({
            "scenario": "delta", "mode": "fleet", "replicas": 8,
            "ckpt_bytes": delta_bytes,
            "amplification": m["remote_bytes"] / kf_bytes,
            **m,
        })
    def _row(mode: str, r: int) -> dict:
        return next(x for x in rows if x["scenario"] == "cold"
                    and x["mode"] == mode and x["replicas"] == r)
    meta = {
        "replicas": list(REPLICAS),
        "bandwidth_mbps": REMOTE_BANDWIDTH_MBPS,
        "latency_s": REMOTE_LATENCY_S,
        "slice_bytes": slice_bytes,
        "keyframe_bytes": kf_bytes,
        "delta_step_bytes": delta_bytes,
        "amp_naive_64": _row("naive", 64)["amplification"],
        "amp_fleet_64": _row("fleet", 64)["amplification"],
        "p99_naive_64": _row("naive", 64)["ready_p99_s"],
        "p99_fleet_64": _row("fleet", 64)["ready_p99_s"],
        "delta_remote_bytes": rows[-1]["remote_bytes"],
        "delta_fraction": rows[-1]["remote_bytes"] / kf_bytes,
    }
    save_results("fig_fleet_warmstart", rows, meta=meta)
    return rows


def check(quick: bool = True) -> int:
    """Re-run the quick figure and gate the fleet's transfer bounds
    against the committed baseline. Returns a process exit status."""
    with open(BASELINE) as f:
        bounds = json.load(f)
    run(quick=quick)
    with open(os.path.join(RESULTS_DIR, "fig_fleet_warmstart.json")) as f:
        meta = json.load(f)["meta"]
    problems: List[str] = []
    if meta["amp_fleet_64"] > bounds["max_amp_fleet_64"]:
        problems.append(
            f"fleet remote-bytes amplification at 64 replicas is "
            f"{meta['amp_fleet_64']:.3f}× one checkpoint, above the "
            f"{bounds['max_amp_fleet_64']}× bound — the single-flight "
            f"cache / peer exchange stopped de-duplicating remote reads")
    if meta["amp_naive_64"] < bounds["min_amp_naive_64"]:
        problems.append(
            f"naive amplification at 64 replicas is only "
            f"{meta['amp_naive_64']:.2f}× (expected ≥ "
            f"{bounds['min_amp_naive_64']}×) — the baseline scenario no "
            f"longer measures per-replica full reads, so the fleet "
            f"comparison is meaningless")
    if meta["amp_fleet_64"] >= meta["amp_naive_64"]:
        problems.append(
            f"fleet ({meta['amp_fleet_64']:.2f}×) did not beat naive "
            f"({meta['amp_naive_64']:.2f}×) on remote bytes at 64 replicas")
    if meta["p99_fleet_64"] > meta["p99_naive_64"] * bounds["max_p99_ratio"]:
        problems.append(
            f"fleet p99 replica-ready time "
            f"({meta['p99_fleet_64'] * 1e3:.0f} ms) exceeds "
            f"{bounds['max_p99_ratio']}× naive "
            f"({meta['p99_naive_64'] * 1e3:.0f} ms) — de-duplicating "
            f"remote reads must not slow the fleet down")
    chain_bound = (meta["delta_step_bytes"] * bounds["delta_chain_overhead"]
                   + bounds["delta_slack_bytes"])
    if meta["delta_remote_bytes"] > chain_bound:
        problems.append(
            f"delta pull moved {meta['delta_remote_bytes']} B remote for "
            f"a {meta['delta_step_bytes']} B delta step (bound "
            f"{chain_bound:.0f} B) — a fleet on step k is re-reading "
            f"more than the chain")
    if meta["delta_fraction"] > bounds["max_delta_fraction"]:
        problems.append(
            f"delta pull cost {meta['delta_fraction']:.3f}× the keyframe "
            f"bytes (max {bounds['max_delta_fraction']}) — the "
            f"delta-aware path has degraded toward full re-reads")
    if problems:
        print("fig_fleet_warmstart REGRESSION:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"fig_fleet_warmstart check OK: amp_fleet_64="
          f"{meta['amp_fleet_64']:.3f}x (naive {meta['amp_naive_64']:.1f}x) "
          f"delta_pull={meta['delta_remote_bytes']} B for a "
          f"{meta['delta_step_bytes']} B chain step")
    return 0


def summarize(rows) -> List[str]:
    lines = []
    for r in rows:
        lines.append(
            f"fig_fleet_warmstart/{r['scenario']}-{r['mode']}-"
            f"{r['replicas']},"
            f"{r['wall_s'] * 1e6:.0f},"
            f"amp={r['amplification']:.2f} "
            f"remote={r['remote_bytes'] >> 10}KiB "
            f"peer={r['peer_bytes'] >> 10}KiB "
            f"p99={r['ready_p99_s'] * 1e3:.0f}ms")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="gate remote-bytes amplification, p99 ordering "
                         "and the delta-chain transfer bound against the "
                         "committed baseline (exit 1 on regression)")
    args = ap.parse_args(argv)
    if args.check:
        return check(quick=True)
    for line in summarize(run(quick=args.quick)):
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
