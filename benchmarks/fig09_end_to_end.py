"""Fig 9: end-to-end training time for N iterations, per-iteration ckpts.

Also captures the paper's no-I/O-tail claim: the final wait for outstanding
flushes is reported separately.
"""

from __future__ import annotations

import time
from typing import List

from .common import (ENGINE_ORDER, TempDir, bench_cfg, make_trainer,
                     manager_for, save_results)


def run(quick: bool = False) -> List[dict]:
    cfg = bench_cfg(2, 512)
    iters = 5 if quick else 15   # the paper uses 15 iterations
    rows = []
    for mode in ENGINE_ORDER:
        with TempDir() as d:
            mgr = manager_for(mode, d)
            tr = make_trainer(cfg, mgr)
            t0 = time.perf_counter()
            tr.run(iters, ckpt_interval=1)
            t_loop = time.perf_counter() - t0
            t0 = time.perf_counter()
            mgr.wait_for_persist()
            t_tail = time.perf_counter() - t0
            mgr.close()
        rows.append({"engine": mode, "iters": iters,
                     "e2e_s": t_loop + t_tail, "loop_s": t_loop,
                     "io_tail_s": t_tail})
    save_results("fig09_end_to_end", rows)
    return rows


def summarize(rows) -> List[str]:
    return [f"fig09/e2e/{r['engine']},{r['e2e_s']*1e6:.0f},"
            f"tail={r['io_tail_s']*1e3:.0f}ms" for r in rows]
