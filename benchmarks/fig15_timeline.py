"""Fig 15: per-tensor multi-tier overlap timeline (stage ∥ flush).

Uses the engine's trace hooks to record (lane, tensor, t0, t1) events and
verifies/visualizes that flushing of early tensors overlaps staging of later
ones — the streamlined pipeline of §V-A4.
"""

from __future__ import annotations

from typing import List

import jax.numpy as jnp

from .common import TempDir, manager_for, save_results


def run(quick: bool = False) -> List[dict]:
    n_tensors = 5
    mb = 4 if quick else 16
    state = {"model": {f"t{i}": jnp.full((mb * (1 << 20) // 4,), i,
                                         jnp.float32)
                       for i in range(n_tensors)},
             "meta": {"step": 0}}
    with TempDir() as d:
        mgr = manager_for("datastates", d, cache_mb=2 * mb * n_tensors)
        trace: list = []
        mgr.engine._engine.trace = trace
        fut = mgr.save(0, state)
        fut.wait_persisted()
        mgr.close()
    t_base = min(t0 for _l, _n, t0, _t1 in trace)
    rows = [{"lane": lane, "tensor": name.split("/")[-1].split("@")[0],
             "t0_ms": (t0 - t_base) * 1e3, "t1_ms": (t1 - t_base) * 1e3}
            for lane, name, t0, t1 in sorted(trace, key=lambda e: e[2])]
    # overlap check: any flush starts before the last stage ends?
    last_stage_end = max(t1 for l, _n, _t0, t1 in trace if l == "stage")
    first_flush = min(t0 for l, _n, t0, _t1 in trace if l == "flush")
    overlap = first_flush < last_stage_end
    save_results("fig15_timeline", rows, meta={"stage_flush_overlap": overlap})
    return [{"overlap": overlap, "events": len(rows)}]


def summarize(rows) -> List[str]:
    r = rows[0]
    return [f"fig15/overlap,0,stage_flush_overlap={r['overlap']} "
            f"events={r['events']}"]
