"""Fig 15: per-tensor multi-tier overlap timeline (stage ∥ flush).

Rebuilt on ckpttrace: the engine's D2H and flush lanes are recorded as
real tracer spans (``d2h.stage`` / ``flush``), so the figure no longer
needs the old hand-rolled ``engine.trace`` hook — it runs one save under
the tracer, extracts those spans, and verifies that flushing of early
tensors overlaps staging of later ones (the streamlined pipeline of
§V-A4). Standalone runs also export the full Chrome trace next to the
JSON results so the exact same save can be opened in Perfetto.
"""

from __future__ import annotations

import os
import time
from typing import List

import jax.numpy as jnp

from .common import RESULTS_DIR, TempDir, active_tracer, manager_for, \
    save_results

LANE = {"d2h.stage": "stage", "flush": "flush"}


def run(quick: bool = False) -> List[dict]:
    n_tensors = 5
    mb = 4 if quick else 16
    state = {"model": {f"t{i}": jnp.full((mb * (1 << 20) // 4,), i,
                                         jnp.float32)
                       for i in range(n_tensors)},
             "meta": {"step": 0}}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    trace_path = os.path.join(RESULTS_DIR, "fig15_timeline.trace.json")
    t_win = time.perf_counter()   # tracer may be shared: window our spans
    with TempDir() as d, active_tracer(trace_path) as t:
        mgr = manager_for("datastates", d, cache_mb=2 * mb * n_tensors)
        fut = mgr.save(0, state)
        fut.wait_persisted()
        mgr.close()
        spans = [e for e in t.spans()
                 if e["name"] in LANE and e["t0"] >= t_win]
    t_base = min(e["t0"] for e in spans)
    rows = []
    for e in sorted(spans, key=lambda e: e["t0"]):
        name = e["args"].get("tensor") or e["args"].get("chunk") or "?"
        rows.append({"lane": LANE[e["name"]],
                     "tensor": name.split("/")[-1].split("@")[0],
                     "t0_ms": (e["t0"] - t_base) * 1e3,
                     "t1_ms": (e["t1"] - t_base) * 1e3})
    # overlap check: any flush starts before the last stage ends?
    last_stage_end = max(e["t1"] for e in spans if e["name"] == "d2h.stage")
    first_flush = min(e["t0"] for e in spans if e["name"] == "flush")
    overlap = first_flush < last_stage_end
    save_results("fig15_timeline", rows, meta={"stage_flush_overlap": overlap,
                                               "trace": trace_path})
    return [{"overlap": overlap, "events": len(rows)}]


def summarize(rows) -> List[str]:
    r = rows[0]
    return [f"fig15/overlap,0,stage_flush_overlap={r['overlap']} "
            f"events={r['events']}"]
