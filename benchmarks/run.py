"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig07,...] \\
        [--trace out.json]

Prints ``name,us_per_call,derived`` CSV per benchmark row and writes full
JSON records to experiments/bench/. ``--trace`` records every figure under
the ckpttrace tracer and exports one Perfetto-loadable Chrome trace per
figure (``out.fig07.json`` etc.; the bare path when one figure runs).
"""

from __future__ import annotations

import argparse
import os
import sys
import time

from . import (fig04_serialization, fig07_throughput, fig08_iteration,
               fig09_end_to_end, fig12_dp_scaling, fig13_frequency,
               fig14_flush, fig15_timeline, fig_breakdown, fig_differential,
               fig_encode, fig_fleet_warmstart, fig_multirank, fig_quantized,
               fig_restore, fig_tiered, table1_heterogeneity,
               table3_breakdown)
from .common import maybe_tracing

MODULES = {
    "fig04": fig04_serialization,
    "fig07": fig07_throughput,
    "fig08": fig08_iteration,
    "fig09": fig09_end_to_end,
    "fig12": fig12_dp_scaling,
    "fig13": fig13_frequency,
    "fig14": fig14_flush,
    "fig15": fig15_timeline,
    "fig_breakdown": fig_breakdown,
    "fig_differential": fig_differential,
    "fig_encode": fig_encode,
    "fig_fleet_warmstart": fig_fleet_warmstart,
    "fig_multirank": fig_multirank,
    "fig_quantized": fig_quantized,
    "fig_restore": fig_restore,
    "fig_tiered": fig_tiered,
    "table1": table1_heterogeneity,
    "table3": table3_breakdown,
}


def _trace_path(template: str, name: str, multi: bool) -> str:
    if not multi:
        return template
    base, ext = os.path.splitext(template)
    return f"{base}.{name}{ext or '.json'}"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig07,table3")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="export a Chrome/Perfetto trace per figure")
    args = ap.parse_args(argv)
    names = (args.only.split(",") if args.only else list(MODULES))
    print("name,us_per_call,derived")
    for name in names:
        mod = MODULES[name]
        trace_path = _trace_path(args.trace, name, len(names) > 1) \
            if args.trace else None
        t0 = time.perf_counter()
        try:
            with maybe_tracing(trace_path):
                rows = mod.run(quick=args.quick)
            for line in mod.summarize(rows):
                print(line)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            raise
        finally:
            sys.stderr.write(f"[{name}: {time.perf_counter()-t0:.1f}s]\n")
            if trace_path and os.path.exists(trace_path):
                sys.stderr.write(f"[{name}: trace -> {trace_path}]\n")


if __name__ == "__main__":
    main()
