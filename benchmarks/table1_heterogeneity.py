"""Table I: 3D checkpoint heterogeneity of a real checkpoint from the
training runtime — file counts, tensor bytes by precision, non-tensor bytes.
"""

from __future__ import annotations

import glob
import os
from typing import List

from repro.core import FileReader

from .common import TempDir, bench_cfg, make_trainer, manager_for, save_results


def run(quick: bool = False) -> List[dict]:
    cfg = bench_cfg(2, 512)
    with TempDir() as d:
        mgr = manager_for("datastates", d)
        tr = make_trainer(cfg, mgr)
        tr.run(1, ckpt_interval=1)
        mgr.wait_for_persist()
        files = sorted(glob.glob(os.path.join(d, "global_step1", "*.dsllm")))
        by_dtype = {}
        non_tensor_bytes = 0
        n_tensors = 0
        for f in files:
            r = FileReader(f)
            for e in r.tensors.values():
                by_dtype[e.dtype] = by_dtype.get(e.dtype, 0) + e.nbytes
                n_tensors += 1
            non_tensor_bytes += sum(o.nbytes for o in r.objects.values())
        mgr.close()
    rows = [{"n_files": len(files), "n_tensors": n_tensors,
             "bytes_by_dtype": by_dtype,
             "non_tensor_bytes": non_tensor_bytes}]
    save_results("table1_heterogeneity", rows)
    return rows


def summarize(rows) -> List[str]:
    r = rows[0]
    fp32 = r["bytes_by_dtype"].get("float32", 0)
    bf16 = r["bytes_by_dtype"].get("bfloat16", 0)
    return [f"table1/heterogeneity,0,files={r['n_files']} "
            f"tensors={r['n_tensors']} fp32={fp32>>20}MB bf16={bf16>>20}MB "
            f"objects={r['non_tensor_bytes']}B"]
