"""Fig (quantized): int8-quantizing the optimizer domain shrinks its bytes ~4×.

ISSUE 5 makes the paper's composable state providers the public API: a
:class:`~repro.core.registry.StateProviderRegistry` routes each leaf of a
named state domain to a provider. The natural first exploit is the "3D
heterogeneity" of real training state — optimizer moments tolerate bounded
loss while parameters do not — so this benchmark quantizes the optimizer
domain (``QuantizedStateProvider``, Pallas int8 kernels, self-contained
``int8q+zstd`` payloads) while the model domain stays raw:

* ``raw``   — stock policy, every tensor streamed raw;
* ``quant`` — ``ProviderRule(domain="optimizer", dtype="float32",
  provider="quantized")`` + auto catch-all.

Workload: equal-sized model + two-moment optimizer state (the Adam
profile: optimizer bytes = 2× model bytes). Both variants save the
identical state; acceptance is ≥3.5× reduction of the optimizer domain's
written bytes and ≥1.8× of the whole step (model stays raw, so the
whole-step cap for this profile is 3 units → 1 + 2×¼ ≈ 2×), capture
latency within 10% of raw (quantization runs on the producer lanes
behind the capture gate), the model domain restoring bit-exact, and the
optimizer moments restoring within the int8 per-row bound (one
quantization step, ``max|row|/127``).
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import (CheckpointManager, CheckpointPolicy, EnginePolicy,
                        StateProviderRegistry, StoragePolicy)

from .common import TempDir, save_results

N_TENSORS = 6                  # per domain entry
SHAPE = (2048, 4096)           # 6 × 8.4M fp32 = 50.3M params / domain entry
SHAPE_QUICK = (512, 1024)
N_SAVES = 4
N_SAVES_QUICK = 3


def _make_state(shape, step: int) -> Dict:
    rng = np.random.default_rng(step)
    model = {f"w{i:02d}": jnp.asarray(
        rng.standard_normal(shape).astype(np.float32))
        for i in range(N_TENSORS)}
    opt = {f"w{i:02d}": {
        "m": jnp.asarray(rng.standard_normal(shape).astype(np.float32)
                         * 1e-2),
        "v": jnp.asarray((rng.standard_normal(shape) ** 2)
                         .astype(np.float32) * 1e-4)}
        for i in range(N_TENSORS)}
    return {"model": model, "optimizer": opt,
            "meta": {"step": step, "note": "fig_quantized"}}


def _quant_registry() -> StateProviderRegistry:
    return (StateProviderRegistry()
            .add_rule(provider="quantized", domain="optimizer",
                      dtype="float32")
            .add_rule(provider="auto"))


def _state_nbytes(state) -> int:
    import jax
    return sum(np.asarray(x).nbytes
               for x in jax.tree_util.tree_leaves(
                   {"model": state["model"],
                    "optimizer": state["optimizer"]}))


def _run_variant(name: str, shape, n_saves: int) -> dict:
    registry = _quant_registry() if name == "quant" else None
    payload = _state_nbytes(_make_state(shape, 0))
    policy = CheckpointPolicy(
        engine=EnginePolicy(host_cache_bytes=int(payload * 1.5) + (64 << 20),
                            flush_threads=4),
        # same convention as fig_differential: measure data movement, not
        # catalog hashing
        storage=StoragePolicy(manifest_checksums=False),
        providers=registry)
    with TempDir() as d:
        mgr = CheckpointManager.from_policy(d, policy)
        captures: List[float] = []
        persists: List[float] = []
        bytes_per_step: List[int] = []
        state = None
        for s in range(1, n_saves + 1):
            state = _make_state(shape, s)
            t0 = time.perf_counter()
            fut = mgr.save(s, state)
            fut.wait_captured()
            captures.append(fut.stats.capture_latency_s)
            fut.wait_persisted()
            persists.append(time.perf_counter() - t0)
            mgr.wait_for_commit(s)
            bytes_per_step.append(mgr.repository.manifest(s).total_bytes)
        # round-trip audit of the final step
        tpl = {"model": {k: np.empty(shape, np.float32)
                         for k in state["model"]},
               "optimizer": {k: {"m": np.empty(shape, np.float32),
                                 "v": np.empty(shape, np.float32)}
                             for k in state["optimizer"]},
               "meta": {"step": 0, "note": ""}}
        t0 = time.perf_counter()
        out = mgr.restore(tpl, step=n_saves)
        restore_s = time.perf_counter() - t0
        model_exact = all(
            np.array_equal(np.asarray(out["model"][k]),
                           np.asarray(state["model"][k]))
            for k in state["model"])
        worst_ratio = 0.0   # |err| / per-row quantization step, max
        for k, moments in state["optimizer"].items():
            for mk in ("m", "v"):
                ref = np.asarray(moments[mk])
                got = np.asarray(out["optimizer"][k][mk])
                # per-row bound in the provider's (256-elem) row space
                flat_r = ref.reshape(-1, 256)
                flat_g = got.reshape(-1, 256)
                step_sz = np.abs(flat_r).max(axis=1, keepdims=True) / 127
                err = np.abs(flat_g - flat_r)
                worst_ratio = max(worst_ratio, float(
                    (err / np.maximum(step_sz, 1e-12)).max()))
        mgr.close()
    return {
        "variant": name, "payload_bytes": payload, "n_saves": n_saves,
        "bytes_written_total": int(sum(bytes_per_step)),
        "bytes_per_step": bytes_per_step,
        "capture_s_best": float(np.min(captures)),
        "capture_s_median": float(np.median(captures)),
        "persist_s_median": float(np.median(persists)),
        "restore_s": restore_s,
        "model_bit_exact": bool(model_exact),
        "opt_worst_err_over_step": worst_ratio,
        "opt_within_int8_tolerance": bool(worst_ratio <= 1.0 + 1e-3),
    }


def run(quick: bool = False) -> List[dict]:
    shape = SHAPE_QUICK if quick else SHAPE
    n_saves = N_SAVES_QUICK if quick else N_SAVES
    rows = [_run_variant(v, shape, n_saves) for v in ("raw", "quant")]
    raw, quant = rows
    # optimizer-domain-only accounting: model + object bytes are identical
    # across variants, so the per-step difference is all optimizer.
    opt_raw = 2 * raw["payload_bytes"] // 3
    for r in rows:
        r["bytes_reduction_vs_raw"] = (
            raw["bytes_written_total"] / max(r["bytes_written_total"], 1))
        r["capture_overhead_vs_raw"] = (
            r["capture_s_best"] / max(raw["capture_s_best"], 1e-9) - 1)
        opt_written = (r["bytes_written_total"]
                       - (raw["bytes_written_total"]
                          - opt_raw * raw["n_saves"]))
        r["opt_bytes_reduction"] = (opt_raw * r["n_saves"]
                                    / max(opt_written, 1))
    save_results("fig_quantized", rows,
                 meta={"shape": list(shape), "n_tensors": N_TENSORS,
                       "note": "optimizer domain = 2x model bytes (Adam); "
                               "registry routes it to the int8 provider, "
                               "model stays raw"})
    return rows


def summarize(rows) -> List[str]:
    lines = []
    for r in rows:
        lines.append(
            f"fig_quantized/{r['variant']},"
            f"{r['persist_s_median'] * 1e6:.0f},"
            f"written={r['bytes_written_total']/1e6:.0f}MB "
            f"capture={r['capture_s_best']*1e3:.0f}ms "
            f"reduction={r['bytes_reduction_vs_raw']:.2f}x")
    quant = next(r for r in rows if r["variant"] == "quant")
    ok = (quant["bytes_reduction_vs_raw"] >= 1.8
          and quant["opt_bytes_reduction"] >= 3.5
          and quant["capture_overhead_vs_raw"] < 0.10
          and quant["model_bit_exact"]
          and quant["opt_within_int8_tolerance"])
    lines.append(
        f"fig_quantized/acceptance,0,"
        f"step_reduction={quant['bytes_reduction_vs_raw']:.2f}x (>=1.8x) "
        f"opt_reduction={quant['opt_bytes_reduction']:.2f}x (>=3.5x) "
        f"capture_overhead={quant['capture_overhead_vs_raw']*100:+.1f}% "
        f"(<10%) model_bit_exact={quant['model_bit_exact']} "
        f"opt_err<=1step={quant['opt_within_int8_tolerance']} "
        f"(worst {quant['opt_worst_err_over_step']:.3f}) "
        f"{'PASS' if ok else 'FAIL'}")
    return lines
