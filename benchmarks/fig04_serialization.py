"""Fig 4: serialization vs write breakdown for type-agnostic engines.

The paper shows torch.save spends a large, nearly size-invariant *fraction*
of checkpoint time serializing an object graph whose payload bytes are
already contiguous (~22%), while the write path reaches only a fraction of
peak. We reproduce with a host-resident dict holding one contiguous tensor:
``sync`` (pickle the whole graph) vs the DataStates state-provider path
(zero-copy memoryview, serialization ≈ 0).
"""

from __future__ import annotations

import os
import pickle
import time
from typing import List

import numpy as np

from .common import TempDir, save_results


def run(quick: bool = False) -> List[dict]:
    sizes_mb = [4, 16, 64] if quick else [4, 16, 64, 256]
    rows = []
    for mb in sizes_mb:
        arr = np.random.default_rng(0).standard_normal(
            mb * (1 << 20) // 8).astype(np.float64)
        obj = {"tensor": arr, "meta": {"step": 1, "names": ["a"] * 100}}
        with TempDir() as d:
            # --- torch.save-analogue: serialize full graph, then write
            t0 = time.perf_counter()
            payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
            t_ser = time.perf_counter() - t0
            t0 = time.perf_counter()
            with open(os.path.join(d, "sync.pkl"), "wb") as f:
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            t_write = time.perf_counter() - t0

            # --- state-provider path: zero-copy view + tiny metadata pickle
            t0 = time.perf_counter()
            view = memoryview(arr).cast("B")          # no copy
            meta_payload = pickle.dumps(obj["meta"])  # only the non-tensor part
            t_ser_sp = time.perf_counter() - t0
            t0 = time.perf_counter()
            fd = os.open(os.path.join(d, "sp.bin"),
                         os.O_WRONLY | os.O_CREAT | os.O_TRUNC)
            os.pwrite(fd, view, 0)
            os.pwrite(fd, meta_payload, len(view))
            os.fsync(fd)
            os.close(fd)
            t_write_sp = time.perf_counter() - t0

        rows.append({
            "size_mb": mb,
            "sync_serialize_s": t_ser, "sync_write_s": t_write,
            "sync_serialize_frac": t_ser / (t_ser + t_write),
            "sp_serialize_s": t_ser_sp, "sp_write_s": t_write_sp,
            "sp_serialize_frac": t_ser_sp / (t_ser_sp + t_write_sp),
        })
    save_results("fig04_serialization", rows)
    return rows


def summarize(rows) -> List[str]:
    out = []
    for r in rows:
        out.append(
            f"fig04/serialize_frac_{r['size_mb']}MB,"
            f"{r['sync_serialize_s']*1e6:.0f},"
            f"sync={r['sync_serialize_frac']:.2f} sp={r['sp_serialize_frac']:.3f}")
    return out
