"""Encode-lane breakdown for the one-pass fused pipeline.

A world=4 save sequence over a mixed registry — delta-encoded model
domain, int8-quantized optimizer domain — is recorded with ckpttrace,
and the figure reduces it to the two artifacts CI gates on:

* the **single-read ratio**: ``engine.bytes_encode_read`` (incremented
  by the fused encoders once per chunk, for exactly the bytes the pass
  consumed) over the bytes the schedule says must be encoded — delta
  domains on delta steps, quantized domains on every step. The fused
  delta→quantize→checksum pass reads each staged byte exactly once, so
  the ratio is 1.0 by construction; a second pass over staged bytes
  (say, a separate checksum sweep creeping back in) doubles it.
* the encode-lane shape: per-save busy seconds split by fused pass
  (``encode.delta`` / ``encode.int8``) vs the flush lanes' downstream
  ``encode.compress``, plus the (d2h ∪ encode) ∥ flush overlap fraction
  — the pipelining floor that keeps the encode lane off the critical
  path.

Gating compares shapes and exact byte accounting, never speeds.
``--check`` re-runs the quick figure against
``benchmarks/baselines/fig_encode_baseline.json``.

    PYTHONPATH=src python -m benchmarks.run --quick --only fig_encode
    PYTHONPATH=src python -m benchmarks.fig_encode --quick --check
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import (CheckpointManager, CheckpointPolicy, DeltaPolicy,
                        DistPolicy, EnginePolicy, StateProviderRegistry,
                        StoragePolicy)
from repro.obs.metrics import metrics as obs_metrics

from .common import RESULTS_DIR, TempDir, active_tracer, save_results

WORLD = 4
LANE_MBPS = 300.0             # emulated per-writer-lane bandwidth
KEYFRAME_EVERY = 2            # saves 1,2,3 = keyframe, delta, keyframe
N_TENSORS = 8
SHAPE = (1024, 4096)          # 8 × 16 MiB fp32 model = 128 MiB
SHAPE_QUICK = (512, 2048)     # 8 × 4 MiB = 32 MiB
OPT_SHAPE = (2048, 4096)      # 32 MiB fp32 optimizer moments
OPT_SHAPE_QUICK = (1024, 2048)
BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "fig_encode_baseline.json")

ENCODE_LANES = ("encode.delta", "encode.int8", "encode.compress")


def _registry() -> StateProviderRegistry:
    return (StateProviderRegistry()
            .add_rule(provider="delta", domain="model")
            .add_rule(provider="quantized", domain="optimizer",
                      dtype="float32")
            .add_rule(provider="auto"))


def _initial_state(shape, opt_shape) -> Dict:
    rng = np.random.default_rng(11)
    model = {f"w{i:02d}": jnp.asarray(
        rng.standard_normal(shape).astype(np.float32))
        for i in range(N_TENSORS)}
    opt = {"m": jnp.asarray(rng.standard_normal(opt_shape)
                            .astype(np.float32))}
    return {"model": model, "optimizer": opt,
            "meta": {"step": 0, "note": "fig_encode"}}


def _mutate(state, step: int) -> Dict:
    model = {k: v.at[::89].add(np.float32(1e-3))
             for k, v in state["model"].items()}
    opt = {"m": state["optimizer"]["m"] * np.float32(1.0 + 1e-4)}
    return {"model": model, "optimizer": opt,
            "meta": {"step": step, "note": "fig_encode"}}


def _merge(ivals: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    out: List[Tuple[float, float]] = []
    for a, b in sorted(ivals):
        if out and a <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], b))
        else:
            out.append((a, b))
    return out


def _busy(ivals) -> float:
    return sum(b - a for a, b in _merge(ivals))


def _intersect_s(xs, ys) -> float:
    xs, ys = _merge(xs), _merge(ys)
    i = j = 0
    total = 0.0
    while i < len(xs) and j < len(ys):
        a = max(xs[i][0], ys[j][0])
        b = min(xs[i][1], ys[j][1])
        if b > a:
            total += b - a
        if xs[i][1] < ys[j][1]:
            i += 1
        else:
            j += 1
    return total


def _window_rows(spans: List[dict], window: Tuple[float, float]) -> dict:
    """Reduce one save's [request, committed] window to encode-lane busy
    seconds per span name, fused byte/span counts, and the overlap
    fraction of production (d2h + encode) against the flush lanes."""
    a, b = window
    enc: Dict[str, List[Tuple[float, float]]] = \
        {k: [] for k in ENCODE_LANES}
    d2h: List[Tuple[float, float]] = []
    flush: List[Tuple[float, float]] = []
    fused_bytes = 0
    fused_spans = 0
    for e in spans:
        if e["t0"] < a or e["t0"] > b:
            continue
        if e["name"] in enc:
            enc[e["name"]].append((e["t0"], e["t1"]))
            if e.get("args", {}).get("fused"):
                fused_bytes += int(e["args"].get("bytes", 0))
                fused_spans += 1
        elif e["name"] == "d2h.stage":
            d2h.append((e["t0"], e["t1"]))
        elif e["name"] == "flush":
            flush.append((e["t0"], e["t1"]))
    produce = d2h + [iv for v in enc.values() for iv in v]
    flush_s = _busy(flush)
    overlap_s = _intersect_s(produce, flush)
    return {
        **{f"{k.split('.')[1]}_s": _busy(v) for k, v in enc.items()},
        "d2h_s": _busy(d2h),
        "flush_s": flush_s,
        "fused_bytes": fused_bytes,
        "fused_spans": fused_spans,
        "overlap_fraction": overlap_s / flush_s if flush_s > 0 else 0.0,
    }


def run(quick: bool = False) -> List[dict]:
    shape = SHAPE_QUICK if quick else SHAPE
    opt_shape = OPT_SHAPE_QUICK if quick else OPT_SHAPE
    state = _initial_state(shape, opt_shape)
    model_bytes = sum(v.nbytes for v in state["model"].values())
    opt_bytes = state["optimizer"]["m"].nbytes
    os.makedirs(RESULTS_DIR, exist_ok=True)
    trace_path = os.path.join(RESULTS_DIR, "fig_encode.trace.json")
    rows: List[dict] = []
    read0 = obs_metrics.get_counter("engine.bytes_encode_read")
    expected_read = 0
    with TempDir() as d, active_tracer(trace_path) as t:
        mgr = CheckpointManager.from_policy(
            d, CheckpointPolicy(
                engine=EnginePolicy(
                    host_cache_bytes=int((model_bytes + opt_bytes) * 2.5)
                    + (64 << 20),
                    flush_threads=1, throttle_mbps=LANE_MBPS),
                storage=StoragePolicy(manifest_checksums=False),
                dist=DistPolicy(world=WORLD),
                delta=DeltaPolicy(keyframe_every=KEYFRAME_EVERY),
                providers=_registry()))
        windows: List[Tuple[int, float, float]] = []
        for s in (1, 2, 3):
            state = _mutate(state, s)
            t0 = time.perf_counter()
            fut = mgr.save(s, state)
            fut.wait_persisted()
            mgr.wait_for_commit(s)
            windows.append((s, t0, time.perf_counter()))
            keyframe = (s - 1) % KEYFRAME_EVERY == 0
            # the schedule's contract: quantized domains encode every
            # save, delta domains only on delta steps
            expected_read += opt_bytes + (0 if keyframe else model_bytes)
            rows.append({
                "step": s,
                "kind": "keyframe" if keyframe else "delta",
                "payload_bytes": model_bytes + opt_bytes,
                "manifest_bytes":
                    mgr.repository.manifest(s).total_bytes,
                "capture_s": fut.stats.capture_latency_s,
                "persist_s": fut.stats.persist_latency_s,
            })
        mgr.close()
        spans = t.spans()
    read_bytes = obs_metrics.get_counter("engine.bytes_encode_read") - read0
    for row, (s, a, b) in zip(rows, windows):
        row.update(_window_rows(spans, (a, b)))
    meta = {
        "world": WORLD, "lane_mbps": LANE_MBPS,
        "keyframe_every": KEYFRAME_EVERY,
        "model_bytes": model_bytes, "opt_bytes": opt_bytes,
        "encode_read_bytes": read_bytes,
        "expected_encode_bytes": expected_read,
        "single_read_ratio": read_bytes / expected_read
        if expected_read else 0.0,
        "fused_span_bytes": sum(r["fused_bytes"] for r in rows),
        "trace": trace_path,
    }
    save_results("fig_encode", rows, meta=meta)
    return rows


def check(quick: bool = True) -> int:
    """Re-run the quick figure and gate the encode-lane invariants
    against the committed baseline. Returns a process exit status."""
    with open(BASELINE) as f:
        bounds = json.load(f)
    rows = run(quick=quick)
    with open(os.path.join(RESULTS_DIR, "fig_encode.json")) as f:
        meta = json.load(f)["meta"]
    problems: List[str] = []
    kinds = [r["kind"] for r in rows]
    if kinds != ["keyframe", "delta", "keyframe"]:
        problems.append(
            f"expected keyframe,delta,keyframe sequence, got {kinds}")
    lo, hi = bounds["single_read_ratio"]
    if not lo <= meta["single_read_ratio"] <= hi:
        problems.append(
            f"single-read ratio {meta['single_read_ratio']:.4f} outside "
            f"[{lo}, {hi}] — staged bytes are no longer read exactly "
            f"once per fused encode "
            f"(read {meta['encode_read_bytes']:.0f} B, schedule expects "
            f"{meta['expected_encode_bytes']} B)")
    if meta["fused_span_bytes"] != meta["encode_read_bytes"]:
        problems.append(
            f"fused span byte attrs ({meta['fused_span_bytes']:.0f} B) "
            f"disagree with engine.bytes_encode_read "
            f"({meta['encode_read_bytes']:.0f} B) — encode "
            f"instrumentation regressed")
    for r in rows:
        rb = bounds["per_kind"][r["kind"]]
        for lane in rb.get("required_lanes", []):
            if r[f"{lane}_s"] <= 0:
                problems.append(
                    f"step {r['step']} ({r['kind']}): required encode "
                    f"lane {lane!r} recorded no busy time")
        for lane in rb.get("forbidden_lanes", []):
            if r[f"{lane}_s"] > 0:
                problems.append(
                    f"step {r['step']} ({r['kind']}): lane {lane!r} ran "
                    f"({r[f'{lane}_s']:.4f}s busy) — keyframes must not "
                    f"pay a delta encode")
        if r["overlap_fraction"] < bounds["min_overlap_fraction"]:
            problems.append(
                f"step {r['step']} ({r['kind']}): overlap fraction "
                f"{r['overlap_fraction']:.3f} < floor "
                f"{bounds['min_overlap_fraction']} — the encode∥flush "
                f"pipeline has collapsed to serial")
    if problems:
        print("fig_encode REGRESSION:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"fig_encode check OK: single_read_ratio="
          f"{meta['single_read_ratio']:.4f} "
          f"({meta['encode_read_bytes']:.0f} B read / "
          f"{meta['expected_encode_bytes']} B scheduled)")
    return 0


def summarize(rows) -> List[str]:
    lines = []
    for r in rows:
        lines.append(
            f"fig_encode/{r['kind']}{r['step']},"
            f"{r['persist_s'] * 1e6:.0f},"
            f"delta={r['delta_s'] * 1e3:.0f}ms "
            f"int8={r['int8_s'] * 1e3:.0f}ms "
            f"compress={r['compress_s'] * 1e3:.0f}ms "
            f"fused={r['fused_bytes'] >> 20}MiB/"
            f"{r['fused_spans']}spans "
            f"overlap={r['overlap_fraction']:.2f}")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="gate the single-read ratio + encode-lane shape "
                         "against the committed baseline (exit 1 on "
                         "regression)")
    args = ap.parse_args(argv)
    if args.check:
        return check(quick=True)
    for line in summarize(run(quick=args.quick)):
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
