"""Fig 8: average training iteration time under per-iteration checkpointing.

Splits per-iteration time into training vs checkpoint-induced stall, per
engine. DataStates should reduce the checkpoint component to near zero.

The stall metric is honest about the end of the run: the trainer folds
its exit drain (waiting for the last save to persist *and commit*) into
the final iteration's ``ckpt_stall_s``, so an engine that defers all its
work to shutdown can't report a near-zero stall here. ``exit_drain_s``
is surfaced per engine so the two components stay distinguishable.
"""

from __future__ import annotations

from typing import List

from .common import (ENGINE_ORDER, TempDir, bench_cfg, make_trainer,
                     manager_for, save_results)


def run(quick: bool = False) -> List[dict]:
    cfg = bench_cfg(2, 512)
    iters = 4 if quick else 10
    rows = []
    # baseline without checkpointing
    tr0 = make_trainer(cfg, None)
    base = tr0.run(iters)
    base_iter = sorted(r.iter_s for r in base)[len(base) // 2]
    for mode in ENGINE_ORDER:
        with TempDir() as d:
            mgr = manager_for(mode, d)
            tr = make_trainer(cfg, mgr)
            recs = tr.run(iters, ckpt_interval=1)
            mgr.close()
        iter_mean = sum(r.iter_s for r in recs[1:]) / (len(recs) - 1)
        stall_mean = sum(r.ckpt_stall_s for r in recs[1:]) / (len(recs) - 1)
        rows.append({"engine": mode, "iter_s": iter_mean,
                     "train_s": base_iter, "ckpt_stall_s": stall_mean,
                     "exit_drain_s": tr.exit_drain_s,
                     "overhead_frac": max(iter_mean - base_iter, 0) / base_iter})
    save_results("fig08_iteration", rows, meta={"baseline_iter_s": base_iter})
    return rows


def summarize(rows) -> List[str]:
    return [f"fig08/iter_time/{r['engine']},{r['iter_s']*1e6:.0f},"
            f"stall={r['ckpt_stall_s']*1e3:.1f}ms" for r in rows]
