"""Restore throughput: the seed's serial restore vs the parallel
RestoreEngine, across all three checkpoint formats.

Runs in a subprocess with 8 virtual devices: a synthetic ≥100M-parameter
fp32 state is sharded over an 8-way data mesh, saved by each engine, and
then restored three ways —

* ``seed-serial``  — a faithful replica of the seed's restore path
  (per-tensor whole-shard reads; the snapshot format re-loads whole rank
  files per tensor, O(files × tensors));
* ``engine-1``     — RestoreEngine with ``threads=1`` (the planning +
  ranged-read machinery, no parallelism: isolates the fan-out win);
* ``engine-8``     — RestoreEngine with ``threads=8``.

Reads are throttled per *stream* at the same ``THROTTLE_MBPS`` the save
benchmarks use: local page cache hides the PFS bandwidth that dominates
restore at scale (arXiv 2512.24511), so — exactly like the write side —
each concurrent read stream is capped at the emulated per-connection
bandwidth. Serial restore owns one stream; the parallel engine opens one
per thread (ByteCheckpoint's parallel re-sharded load). Unthrottled
wall-clock rows are recorded too so the raw local-SSD effect (ranged
``preadv`` vs per-tensor memmap faulting) is visible separately.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List

from .common import THROTTLE_MBPS, save_results

_CHILD = r"""
import glob, json, os, pickle, re, sys, tempfile, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("REPRO_NO_FSYNC", "1")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import (CheckpointManager, CheckpointPolicy,
                        EnginePolicy, RestoreEngine, step_dir)
from repro.core.baselines import load_snapshot_rank, load_sync_rank
from repro.core.distributed import _path_str
from repro.core.layout import FileReader
from repro.launch.mesh import make_mesh

N_TENSORS = %(n_tensors)d
SHAPE = (%(rows)d, %(cols)d)
THROTTLE = %(throttle)f

mesh = make_mesh((8,), ("data",))
shard = NamedSharding(mesh, P("data", None))
key = jax.random.PRNGKey(0)
state = {"model": {}, "meta": {"step": 0, "note": "fig_restore"}}
for i in range(N_TENSORS):
    key, sub = jax.random.split(key)
    state["model"]["w%%02d" %% i] = jax.device_put(
        jax.random.normal(sub, SHAPE, jnp.float32), shard)
payload = sum(v.nbytes for v in state["model"].values())

# host-side template: isolates the storage->host path being compared (the
# device_put cost of a sharded target is identical for every variant)
tpl = {"model": {k: np.empty(SHAPE, np.float32) for k in state["model"]},
       "meta": {"step": 0, "note": ""}}


# --- faithful replica of the seed's serial restore ------------------------
# (checkpoint.py@de9b523: _index_step_dir + _assemble), instrumented with a
# byte counter and an optional single-stream read throttle.
def seed_restore(sdir, template, throttle_mbps=None):
    read_bytes = [0]

    def throttled(nb, t0):
        read_bytes[0] += nb
        if throttle_mbps:
            target = nb / (throttle_mbps * 1e6)
            el = time.perf_counter() - t0
            if target > el:
                time.sleep(target - el)

    tensor_index, object_index = {}, {}
    dsllm = sorted(glob.glob(os.path.join(sdir, "*.dsllm")))
    manifests = sorted(glob.glob(os.path.join(sdir, "manifest_rank*.pkl")))
    if dsllm:
        for p in dsllm:
            rd = FileReader(p)
            for name, entry in rd.tensors.items():
                base = name.split("@[", 1)[0]

                def read(r=rd, n=entry.name, nb=entry.nbytes):
                    t0 = time.perf_counter()
                    out = np.array(r.read_tensor(n))   # full-shard read
                    throttled(nb, t0)
                    return out
                tensor_index.setdefault(base, []).append((entry.index, read))
            for oname in rd.objects:
                object_index[oname] = (lambda r=rd, n=oname:
                                       r.read_object(n))
    elif manifests:
        for mpath in manifests:
            with open(mpath, "rb") as f:
                manifest = pickle.load(f)
            rank = int(re.search(r"manifest_rank(\d+)", mpath).group(1))
            rank_bytes = sum(hi - lo for t in manifest["tensors"]
                             for _, lo, hi in t["chunks"])
            for t in manifest["tensors"]:
                base = t["name"].split("@[", 1)[0]

                def read(d=os.path.dirname(mpath), r=rank, n=t["name"],
                         nb=rank_bytes):
                    t0 = time.perf_counter()
                    out = load_snapshot_rank(d, r)[n]  # whole-rank re-read!
                    throttled(nb, t0)
                    return out
                tensor_index.setdefault(base, []).append(
                    (tuple(t["index"]), read))
        opath = os.path.join(sdir, "objects.pkl")
        if os.path.exists(opath):
            with open(opath, "rb") as f:
                objects = pickle.load(f)
            for oname, val in objects.items():
                object_index[oname] = (lambda v=val: v)
    else:
        for p in sorted(glob.glob(os.path.join(sdir, "*.pkl"))):
            t0 = time.perf_counter()
            graph = load_sync_rank(p)
            throttled(os.path.getsize(p), t0)
            for name, rec in graph.items():
                if name == "__objects__":
                    for oname, val in rec.items():
                        object_index[oname] = (lambda v=val: v)
                    continue
                base = name.split("@[", 1)[0]
                tensor_index.setdefault(base, []).append(
                    (tuple(rec["index"]), (lambda r=rec: r["data"])))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves:
        pstr = "state/" + _path_str(path)
        if isinstance(leaf, np.ndarray):
            region = tuple((0, d) for d in leaf.shape)
            buf = np.empty(leaf.shape, dtype=leaf.dtype)
            for s_idx, read in tensor_index[pstr]:
                inter = tuple((max(a, c), min(b, d))
                              for (a, b), (c, d) in zip(region, s_idx))
                if any(lo >= hi for lo, hi in inter):
                    continue
                src = read()
                src_sl = tuple(slice(lo - c, hi - c)
                               for (lo, hi), (c, _d) in zip(inter, s_idx))
                dst_sl = tuple(slice(lo - a, hi - a)
                               for (lo, hi), (a, _b) in zip(inter, region))
                buf[dst_sl] = src[src_sl]
            out.append(buf)
        else:
            out.append(object_index[pstr]() if pstr in object_index else leaf)
    return jax.tree_util.tree_unflatten(treedef, out), read_bytes[0]


def check(tree):
    ref = np.asarray(state["model"]["w00"])
    np.testing.assert_array_equal(np.asarray(tree["model"]["w00"]), ref)


rows = []
for mode in ("datastates", "snapshot", "sync"):
    d = tempfile.mkdtemp(prefix="fig_restore_")
    mgr = CheckpointManager.from_policy(
        d, CheckpointPolicy(engine=EnginePolicy(
            mode=mode, host_cache_bytes=1 << 30)))
    mgr.save(0, state, blocking=True)
    mgr.close()
    sdir = step_dir(d, 0)
    ckpt_bytes = sum(os.path.getsize(os.path.join(sdir, f))
                     for f in os.listdir(sdir))

    variants = [("seed-serial", None, True), ("engine-1", 1, True),
                ("engine-8", 8, True)]
    if mode == "datastates":
        variants += [("seed-serial", None, False), ("engine-8", 8, False)]
    for variant, threads, throttled_run in variants:
        throttle = THROTTLE if throttled_run else None
        t0 = time.perf_counter()
        if threads is None:
            tree, nbytes = seed_restore(sdir, tpl, throttle_mbps=throttle)
            n_ranges = -1
        else:
            eng = RestoreEngine(threads=threads, throttle_mbps=throttle)
            tree, stats = eng.restore(sdir, tpl)
            nbytes, n_ranges = stats.bytes_read, stats.n_ranges
        dt = time.perf_counter() - t0
        check(tree)
        rows.append({"format": mode, "variant": variant,
                     "throttled": bool(throttled_run), "seconds": dt,
                     "gbps": payload / dt / 1e9,
                     "bytes_read": int(nbytes),
                     "ckpt_bytes": int(ckpt_bytes),
                     "payload_bytes": int(payload),
                     "n_ranges": int(n_ranges)})
        del tree
    for f in os.listdir(sdir):
        os.unlink(os.path.join(sdir, f))
print("RESULT " + json.dumps(rows))
"""


def run(quick: bool = False) -> List[dict]:
    # 13 x 1024 x 7872 fp32 = 104.8M params (400 MiB); quick: 16.8M (64 MiB)
    n_tensors, rows_, cols = (8, 256, 8192) if quick else (13, 1024, 7872)
    code = _CHILD % {"n_tensors": n_tensors, "rows": rows_, "cols": cols,
                     "throttle": THROTTLE_MBPS}
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"fig_restore child failed:\n{out.stdout}\n"
                           f"{out.stderr}")
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rows = json.loads(line[len("RESULT "):])
    save_results("fig_restore", rows,
                 meta={"n_tensors": n_tensors,
                       "shape": [rows_, cols],
                       "read_throttle_per_stream_mbps": THROTTLE_MBPS})
    return rows


def summarize(rows) -> List[str]:
    lines = []
    by = {(r["format"], r["variant"], r["throttled"]): r for r in rows}
    for fmt in ("datastates", "snapshot", "sync"):
        seed = by.get((fmt, "seed-serial", True))
        par = by.get((fmt, "engine-8", True))
        if seed and par:
            lines.append(
                f"fig_restore/{fmt}/throttled,0,"
                f"seed={seed['seconds']:.2f}s "
                f"par={par['seconds']:.2f}s "
                f"speedup={seed['seconds'] / par['seconds']:.2f}x")
    seed_u = by.get(("datastates", "seed-serial", False))
    par_u = by.get(("datastates", "engine-8", False))
    if seed_u and par_u:
        lines.append(f"fig_restore/datastates/unthrottled,0,"
                     f"seed={seed_u['seconds']:.2f}s "
                     f"par={par_u['seconds']:.2f}s "
                     f"speedup={seed_u['seconds'] / par_u['seconds']:.2f}x")
    snap_seed = by.get(("snapshot", "seed-serial", True))
    snap_eng = by.get(("snapshot", "engine-8", True))
    if snap_seed and snap_eng:
        lines.append(
            f"fig_restore/snapshot/bytes_read,0,"
            f"seed={snap_seed['bytes_read'] / 2**30:.2f}GiB "
            f"engine={snap_eng['bytes_read'] / 2**30:.2f}GiB "
            f"ckpt={snap_eng['ckpt_bytes'] / 2**30:.2f}GiB")
    return lines
