"""Fig (differential): bytes written shrink ≥3× on slowly-moving state.

The paper's §VII names data reduction as the next lever once lazy async
snapshots and multi-writer I/O stop being the bottleneck — at high
checkpoint frequency the dominant cost is *bytes written* (ByteCheckpoint
arXiv 2407.20143; checkpoint-I/O study arXiv 2512.24511). This benchmark
puts differential checkpointing on the main engine path head-to-head with
full snapshots:

* ``full``  — the stock ``datastates`` engine, one full snapshot per save;
* ``delta`` — ``DeltaPolicy(keyframe_every=4)``: raw keyframe every 4th
  save, XOR deltas (Pallas kernel) in between, compressed per chunk on
  the flush lanes (``codec="xor+zstd"``), committed through the same
  catalog with chain metadata.

Workload: the 104.8M-parameter fp32 state of fig_restore (13 × 1024 ×
7872), mutated sparsely between saves (~1% of rows — the slowly-moving
optimizer-moment profile). Both variants save the *identical* state
sequence, so the final restored bytes must agree checksum-for-checksum.

Acceptance (ISSUE 4): ≥3× reduction in total bytes written across the
save sequence at keyframe_every=4, <10% added capture latency, and the
delta-chain restore through RestoreEngine is bit-exact (checksums match
the full-snapshot restore).
"""

from __future__ import annotations

import hashlib
import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import (CheckpointManager, CheckpointPolicy,
                        DeltaPolicy, EnginePolicy, StoragePolicy)

from .common import TempDir, save_results

N_TENSORS = 13
SHAPE = (1024, 7872)          # 13 × 1024 × 7872 fp32 = 104.8M params
SHAPE_QUICK = (512, 2624)     # 17.5M params (quick/CI smoke)
N_SAVES = 8                   # K=4 ⇒ keyframes at saves 1 and 5
N_SAVES_QUICK = 8             # same cadence: the ≥3× bound needs ≥2 deltas
                              # amortized per keyframe
KEYFRAME_EVERY = 4
MUTATE_ROWS = 101             # ~1% of rows touched between saves


def _initial_state(shape) -> Dict:
    rng = np.random.default_rng(0)
    model = {f"w{i:02d}": jnp.asarray(
        rng.standard_normal(shape).astype(np.float32))
        for i in range(N_TENSORS)}
    return {"model": model, "meta": {"step": 0, "note": "fig_differential"}}


def _mutate(state, step: int) -> Dict:
    """Sparse drift: every MUTATE_ROWS-th row moves slightly (slowly-
    moving optimizer state: most bytes identical save-to-save)."""
    model = {k: v.at[::MUTATE_ROWS].add(np.float32(1e-3))
             for k, v in state["model"].items()}
    return {"model": model, "meta": {"step": step,
                                     "note": "fig_differential"}}


def _state_nbytes(state) -> int:
    return sum(v.nbytes for v in state["model"].values())


def _tree_digest(tree) -> str:
    h = hashlib.sha256()
    for k in sorted(tree["model"]):
        h.update(np.asarray(tree["model"][k]).tobytes())
    return h.hexdigest()


def _run_variant(name: str, shape, n_saves: int) -> dict:
    delta = DeltaPolicy(keyframe_every=KEYFRAME_EVERY) \
        if name == "delta" else None
    state = _initial_state(shape)
    payload = _state_nbytes(state)
    with TempDir() as d:
        mgr = CheckpointManager.from_policy(
            d, CheckpointPolicy(
                engine=EnginePolicy(
                    host_cache_bytes=int(payload * 2.5) + (64 << 20),
                    flush_threads=4),
                storage=StoragePolicy(manifest_checksums=False),
                delta=delta))
        captures: List[float] = []
        persists: List[float] = []
        bytes_per_step: List[int] = []
        for s in range(1, n_saves + 1):
            state = _mutate(state, s)
            t0 = time.perf_counter()
            fut = mgr.save(s, state)
            fut.wait_captured()
            captures.append(fut.stats.capture_latency_s)
            fut.wait_persisted()
            persists.append(time.perf_counter() - t0)
            mgr.wait_for_commit(s)
            bytes_per_step.append(mgr.repository.manifest(s).total_bytes)
        # restore the final (delta) step through the engine path
        tpl = {"model": {k: np.empty(shape, np.float32)
                         for k in state["model"]},
               "meta": {"step": 0, "note": ""}}
        t0 = time.perf_counter()
        out = mgr.restore(tpl, step=n_saves)
        restore_s = time.perf_counter() - t0
        rstats = mgr.last_restore_stats
        digest = _tree_digest(out)
        exact = digest == _tree_digest(state)
        kinds = []
        for s in range(1, n_saves + 1):
            meta = mgr.repository.manifest(s).meta.get("delta") or {}
            kinds.append("k" if meta.get("keyframe", True) else "d")
        mgr.close()
    return {
        "variant": name, "payload_bytes": payload, "n_saves": n_saves,
        "bytes_written_total": int(sum(bytes_per_step)),
        "bytes_per_step": bytes_per_step,
        "save_kinds": "".join(kinds),
        # best-of is the intrinsic capture latency (same convention as
        # fig_multirank): medians at the quick scale (~20 ms captures)
        # are dominated by scheduler jitter, not engine behaviour
        "capture_s_best": float(np.min(captures)),
        "capture_s_median": float(np.median(captures)),
        "persist_s_median": float(np.median(persists)),
        "restore_s": restore_s,
        "restore_bytes_read": rstats.bytes_read,
        "restore_digest": digest,
        "restore_bit_exact_vs_memory": exact,
    }


def run(quick: bool = False) -> List[dict]:
    shape = SHAPE_QUICK if quick else SHAPE
    n_saves = N_SAVES_QUICK if quick else N_SAVES
    rows = [_run_variant(v, shape, n_saves) for v in ("full", "delta")]
    full, delta = rows
    for r in rows:
        r["bytes_reduction_vs_full"] = (
            full["bytes_written_total"] / max(r["bytes_written_total"], 1))
        r["capture_overhead_vs_full"] = (
            r["capture_s_best"] / max(full["capture_s_best"], 1e-9) - 1)
        r["restore_matches_full"] = (
            r["restore_digest"] == full["restore_digest"])
    save_results("fig_differential", rows,
                 meta={"keyframe_every": KEYFRAME_EVERY,
                       "mutate_rows": MUTATE_ROWS, "shape": list(shape),
                       "note": "identical state sequence both variants; "
                               "manifest checksums off (movement, not "
                               "hashing)"})
    return rows


def summarize(rows) -> List[str]:
    lines = []
    for r in rows:
        lines.append(
            f"fig_differential/{r['variant']},"
            f"{r['persist_s_median'] * 1e6:.0f},"
            f"written={r['bytes_written_total']/1e6:.0f}MB "
            f"({r['save_kinds']}) "
            f"capture={r['capture_s_best']*1e3:.0f}ms "
            f"reduction={r['bytes_reduction_vs_full']:.2f}x")
    delta = next(r for r in rows if r["variant"] == "delta")
    ok = (delta["bytes_reduction_vs_full"] >= 3.0
          and delta["capture_overhead_vs_full"] < 0.10
          and delta["restore_bit_exact_vs_memory"]
          and delta["restore_matches_full"])
    lines.append(
        f"fig_differential/acceptance,0,"
        f"reduction={delta['bytes_reduction_vs_full']:.2f}x (>=3x) "
        f"capture_overhead={delta['capture_overhead_vs_full']*100:+.1f}% "
        f"(<10%) chain_restore_bit_exact="
        f"{delta['restore_bit_exact_vs_memory'] and delta['restore_matches_full']} "
        f"{'PASS' if ok else 'FAIL'}")
    return lines
