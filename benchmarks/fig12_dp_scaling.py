"""Figs 10–12: checkpointing under increasing data parallelism.

Runs in a subprocess with 8 virtual devices. The optimizer state is sharded
over the ``data`` axis (ZeRO-1, the paper's setup): growing DP shrinks the
per-rank checkpoint payload (minor axis of Fig 12) while adding concurrent
writers. We measure per-rank bytes and effective blocked-time throughput for
DP ∈ {1, 2, 4, 8}.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List

from .common import save_results

_CHILD = r"""
import os, json, time, tempfile, shutil
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import CheckpointManager, CheckpointPolicy, EnginePolicy
from repro.launch.mesh import make_mesh

results = []
n_total = 8 * (1 << 20) // 4          # 8 MiB of fp32 "optimizer state"
for dp in (1, 2, 4, 8):
    mesh = make_mesh((dp, 1), ("data", "model"))
    # ZeRO-1: optimizer state sharded over data; params replicated
    opt = jax.device_put(jnp.arange(n_total, dtype=jnp.float32),
                         NamedSharding(mesh, P("data")))
    params = jax.device_put(jnp.ones((1 << 18,), jnp.bfloat16),
                            NamedSharding(mesh, P()))
    state = {"model": {"w": params}, "optimizer": {"m": opt},
             "meta": {"dp": dp}}
    d = tempfile.mkdtemp()
    mgr = CheckpointManager.from_policy(
        d, CheckpointPolicy(engine=EnginePolicy(
            host_cache_bytes=128 << 20, throttle_mbps=600.0)))
    fut = mgr.save(0, state)
    fut.wait_persisted()
    stats = fut.stats
    files = os.listdir(os.path.join(d, "global_step0"))
    per_rank = stats.bytes_tensors / max(dp, 1)
    results.append({"dp": dp, "n_files": len(files),
                    "total_mb": stats.bytes_tensors / 2**20,
                    "per_rank_mb": per_rank / 2**20,
                    "blocking_s": stats.blocking_s,
                    "persist_s": stats.persist_latency_s})
    mgr.close()
    shutil.rmtree(d, ignore_errors=True)
print(json.dumps(results))
"""


def run(quick: bool = False) -> List[dict]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    save_results("fig12_dp_scaling", rows)
    return rows


def summarize(rows) -> List[str]:
    return [f"fig12/dp{r['dp']},{r['blocking_s']*1e6:.0f},"
            f"per_rank={r['per_rank_mb']:.1f}MB files={r['n_files']}"
            for r in rows]
