"""Table III: per-checkpoint sub-operation breakdown per engine:
metadata/serialize vs GPU→host staging vs host→file flush, and which of
those block training."""

from __future__ import annotations

from typing import List

from .common import (ENGINE_ORDER, TempDir, bench_cfg, make_trainer,
                     manager_for, save_results, state_nbytes)


def run(quick: bool = False) -> List[dict]:
    cfg = bench_cfg(2, 512)
    rows = []
    for mode in ENGINE_ORDER:
        with TempDir() as d:
            mgr = manager_for(mode, d)
            tr = make_trainer(cfg, mgr)
            tr.run(2, ckpt_interval=2)
            mgr.wait_for_persist()
            fut = [f for f in mgr._inflight][-1]
            s = fut.stats
            rows.append({
                "engine": mode,
                "bytes": s.total_bytes,
                "serialize_s": s.serialize_s,
                "stage_s": s.stage_s,
                "flush_s": s.flush_s,
                "blocking_s": s.blocking_s,
                "capture_latency_s": s.capture_latency_s,
                "persist_latency_s": s.persist_latency_s,
            })
            mgr.close()
    save_results("table3_breakdown", rows)
    return rows


def summarize(rows) -> List[str]:
    return [f"table3/{r['engine']},{r['blocking_s']*1e6:.0f},"
            f"ser={r['serialize_s']*1e3:.1f}ms stage={r['stage_s']*1e3:.1f}ms "
            f"flush={r['flush_s']*1e3:.1f}ms" for r in rows]
