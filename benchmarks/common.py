"""Shared benchmark substrate.

All checkpoint benchmarks run REAL engines against REAL files on local disk.
To emulate the paper's bandwidth-limited PFS (and make engine differences
visible on a fast local SSD), engines are configured with a per-thread write
throttle (``THROTTLE_MBPS``); the same throttle applies to every engine, so
*relative* comparisons — the paper's claims — are preserved. Results record
the throttle so EXPERIMENTS.md can state the methodology.
"""

from __future__ import annotations

import os as _os
# Benchmark mode: skip fsync — this VM's disk fsyncs at an erratic 18-44
# MB/s, which would swamp the controlled write throttle that emulates the
# paper's PFS bandwidth. Relative engine comparisons need the throttle to
# be the binding constraint. (Production paths fsync normally.)
_os.environ.setdefault("REPRO_NO_FSYNC", "1")

import contextlib
import dataclasses
import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core import CheckpointManager
from repro.training.loop import Trainer

THROTTLE_MBPS = 600.0          # emulated storage bandwidth per flush thread
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench")

ENGINE_ORDER = ["sync", "snapshot", "datastates-old", "datastates"]
ENGINE_LABEL = {
    "sync": "DeepSpeed-default (torch.save-like)",
    "snapshot": "TorchSnapshot-like",
    "datastates-old": "DataStates-LLM-Old (HPDC'24)",
    "datastates": "DataStates-LLM (this paper)",
}


def bench_cfg(n_layers: int = 2, d_model: int = 256, vocab: int = 2048):
    """Scaled llama2-family config (the paper's Table II family)."""
    cfg = smoke_variant(get_config("llama2-7b"))
    return dataclasses.replace(
        cfg, name=f"llama2-bench-L{n_layers}-d{d_model}",
        n_layers=n_layers, d_model=d_model, d_ff=4 * d_model, vocab=vocab,
        n_heads=4, n_kv_heads=4, head_dim=0,
        layer_groups=((("full",) * min(n_layers, 2),
                       max(1, n_layers // min(n_layers, 2))),))


def state_nbytes(state) -> int:
    return sum(l.nbytes for l in jax.tree_util.tree_leaves(state)
               if hasattr(l, "nbytes"))


def make_trainer(cfg, manager: Optional[CheckpointManager], batch=2,
                 seq_len=64) -> Trainer:
    return Trainer(cfg, batch=batch, seq_len=seq_len, manager=manager)


def manager_for(mode: str, directory: str, *, cache_mb: int = 1536,
                throttle: Optional[float] = THROTTLE_MBPS,
                flush_threads: int = 4) -> CheckpointManager:
    from repro.core import CheckpointPolicy, EnginePolicy
    return CheckpointManager.from_policy(
        directory, CheckpointPolicy(engine=EnginePolicy(
            mode=mode, host_cache_bytes=cache_mb << 20,
            flush_threads=flush_threads, throttle_mbps=throttle)))


@contextlib.contextmanager
def maybe_tracing(path: Optional[str]):
    """``--trace out.json`` support for the benchmark harness.

    ``path=None`` is a no-op (tracing stays off, so the <1%-when-disabled
    guarantee holds for untraced runs). Otherwise the ckpttrace tracer is
    enabled for the enclosed figure and a Perfetto-loadable Chrome trace
    is exported to ``path`` on exit."""
    if not path:
        yield None
        return
    from repro.obs import tracing
    with tracing(path) as t:
        yield t


@contextlib.contextmanager
def active_tracer(export_path: Optional[str] = None):
    """Yield a live tracer for figures whose *measurement* is trace spans.

    When the harness already enabled tracing (``benchmarks.run --trace``)
    that tracer is reused, so the figure's spans land in the harness
    export; standalone runs get a local tracer for the duration, exported
    to ``export_path`` if given."""
    from repro.obs import trace as _trace
    t = _trace.get_tracer()
    if t is not None:
        yield t
        return
    with _trace.tracing(export_path) as t:
        yield t


def save_results(name: str, rows: List[Dict[str, Any]],
                 meta: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump({"name": name, "throttle_mbps": THROTTLE_MBPS,
                   "meta": meta or {}, "rows": rows}, f, indent=2,
                  default=float)
    return path


class TempDir:
    def __enter__(self):
        self.path = tempfile.mkdtemp(prefix="dsllm_bench_")
        return self.path

    def __exit__(self, *exc):
        shutil.rmtree(self.path, ignore_errors=True)
        return False
