"""Per-stage save breakdown (capture / D2H / encode / flush / commit).

A world=4 coordinated differential save sequence is recorded with
ckpttrace; the figure reduces the span timeline to the artifact CI
actually gates on:

* per-step busy seconds for each pipeline stage — ``d2h`` (device→host
  staging), ``encode`` (delta XOR + zstd + int8), ``flush`` (file I/O),
  ``commit`` (catalog publish) — computed as merged-interval unions, so
  four ranks flushing concurrently count wall seconds, not lane-seconds;
* the *overlap fraction*: seconds the flush lanes were writing while
  staging or encode was simultaneously running, over total flush busy
  time. This is the paper's pipelining claim in one number — 0 means a
  serial stage→write pipeline, anything material means the lanes overlap.

Regression gating compares **shapes, not speeds**: stage shares and
overlap fractions are stable across machines, absolute times are not.
``--check`` re-runs the quick breakdown and exits non-zero if the
committed bounds in ``benchmarks/baselines/fig_breakdown_baseline.json``
are violated.

    PYTHONPATH=src python -m benchmarks.run --quick --only fig_breakdown
    PYTHONPATH=src python -m benchmarks.fig_breakdown --quick --check
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import (CheckpointManager, CheckpointPolicy, DeltaPolicy,
                        DistPolicy, EnginePolicy, StoragePolicy)

from .common import RESULTS_DIR, TempDir, active_tracer, save_results

WORLD = 4
LANE_MBPS = 300.0             # emulated per-writer-lane bandwidth
KEYFRAME_EVERY = 2            # save 1 = keyframe, save 2 = delta
N_TENSORS = 12
SHAPE = (1024, 4096)          # 12 × 16 MiB fp32 = 192 MiB
SHAPE_QUICK = (512, 2048)     # 12 × 4 MiB = 48 MiB
BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "fig_breakdown_baseline.json")

STAGE_SPANS = {
    "d2h": lambda n: n == "d2h.stage",
    "encode": lambda n: n.startswith("encode."),
    "flush": lambda n: n == "flush",
    "commit": lambda n: n == "commit",
}


def _initial_state(shape) -> Dict:
    rng = np.random.default_rng(7)
    model = {f"w{i:02d}": jnp.asarray(
        rng.standard_normal(shape).astype(np.float32))
        for i in range(N_TENSORS)}
    return {"model": model, "meta": {"step": 0, "note": "fig_breakdown"}}


def _mutate(state, step: int) -> Dict:
    model = {k: v.at[::97].add(np.float32(1e-3))
             for k, v in state["model"].items()}
    return {"model": model, "meta": {"step": step, "note": "fig_breakdown"}}


def _merge(ivals: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    out: List[Tuple[float, float]] = []
    for a, b in sorted(ivals):
        if out and a <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], b))
        else:
            out.append((a, b))
    return out


def _busy(ivals) -> float:
    return sum(b - a for a, b in _merge(ivals))


def _intersect_s(xs, ys) -> float:
    """Total seconds the merged unions of two interval sets coincide."""
    xs, ys = _merge(xs), _merge(ys)
    i = j = 0
    total = 0.0
    while i < len(xs) and j < len(ys):
        a = max(xs[i][0], ys[j][0])
        b = min(xs[i][1], ys[j][1])
        if b > a:
            total += b - a
        if xs[i][1] < ys[j][1]:
            i += 1
        else:
            j += 1
    return total


def _breakdown(spans: List[dict], window: Tuple[float, float]) -> dict:
    """Reduce the spans inside one save's [request, committed] window to
    per-stage busy seconds plus the overlap fraction."""
    a, b = window
    ivals: Dict[str, List[Tuple[float, float]]] = \
        {k: [] for k in STAGE_SPANS}
    for e in spans:
        if e["t0"] < a or e["t0"] > b:
            continue
        for stage, match in STAGE_SPANS.items():
            if match(e["name"]):
                ivals[stage].append((e["t0"], e["t1"]))
    busy = {k: _busy(v) for k, v in ivals.items()}
    produce = ivals["d2h"] + ivals["encode"]
    overlap_s = _intersect_s(produce, ivals["flush"])
    return {
        **{f"{k}_s": v for k, v in busy.items()},
        "overlap_s": overlap_s,
        "overlap_fraction": overlap_s / busy["flush"]
        if busy["flush"] > 0 else 0.0,
    }


def run(quick: bool = False) -> List[dict]:
    shape = SHAPE_QUICK if quick else SHAPE
    state = _initial_state(shape)
    payload = sum(v.nbytes for v in state["model"].values())
    os.makedirs(RESULTS_DIR, exist_ok=True)
    trace_path = os.path.join(RESULTS_DIR, "fig_breakdown.trace.json")
    rows: List[dict] = []
    with TempDir() as d, active_tracer(trace_path) as t:
        mgr = CheckpointManager.from_policy(
            d, CheckpointPolicy(
                engine=EnginePolicy(
                    host_cache_bytes=int(payload * 2.5) + (64 << 20),
                    flush_threads=1, throttle_mbps=LANE_MBPS),
                storage=StoragePolicy(manifest_checksums=False),
                dist=DistPolicy(world=WORLD),
                delta=DeltaPolicy(keyframe_every=KEYFRAME_EVERY)))
        windows: List[Tuple[int, float, float]] = []
        for s in (1, 2):
            state = _mutate(state, s)
            t0 = time.perf_counter()
            fut = mgr.save(s, state)
            fut.wait_persisted()
            mgr.wait_for_commit(s)
            windows.append((s, t0, time.perf_counter()))
            kind = (mgr.repository.manifest(s).meta.get("delta")
                    or {})
            rows.append({
                "step": s,
                "kind": "keyframe" if kind.get("keyframe", True)
                else "delta",
                "payload_bytes": payload,
                "manifest_bytes":
                    mgr.repository.manifest(s).total_bytes,
                "capture_s": fut.stats.capture_latency_s,
                "persist_s": fut.stats.persist_latency_s,
            })
        mgr.close()
        spans = t.spans()
        lanes = {e["lane"] for e in spans}
    rank_lanes = sorted({ln.split("-")[0] for ln in lanes
                         if ln.startswith("rank")})
    for row, (s, a, b) in zip(rows, windows):
        row.update(_breakdown(spans, (a, b)))
    # the pipelining claim across the whole sequence (keyframe overlaps
    # d2h∥flush, delta overlaps encode∥flush)
    all_ivals = [(w[1], w[2]) for w in windows]
    overall = _breakdown(spans, (min(a for a, _ in all_ivals),
                                 max(b for _, b in all_ivals)))
    meta = {
        "world": WORLD, "lane_mbps": LANE_MBPS,
        "keyframe_every": KEYFRAME_EVERY,
        "rank_lanes": rank_lanes,
        "overall_overlap_fraction": overall["overlap_fraction"],
        "trace": trace_path,
    }
    save_results("fig_breakdown", rows, meta=meta)
    return rows


def check(quick: bool = True) -> int:
    """Re-run the quick breakdown and gate it against the committed
    baseline bounds. Returns a process exit status (0 = pass)."""
    with open(BASELINE) as f:
        bounds = json.load(f)
    rows = run(quick=quick)
    with open(os.path.join(RESULTS_DIR, "fig_breakdown.json")) as f:
        meta = json.load(f)["meta"]
    problems: List[str] = []
    kinds = [r["kind"] for r in rows]
    if kinds != ["keyframe", "delta"]:
        problems.append(f"expected keyframe+delta sequence, got {kinds}")
    if len(meta["rank_lanes"]) < bounds["min_rank_lanes"]:
        problems.append(
            f"only {len(meta['rank_lanes'])} rank lanes in trace "
            f"(need >= {bounds['min_rank_lanes']}): {meta['rank_lanes']}")
    if meta["overall_overlap_fraction"] < bounds["min_overlap_fraction"]:
        problems.append(
            f"overlap fraction {meta['overall_overlap_fraction']:.3f} "
            f"< baseline floor {bounds['min_overlap_fraction']} — the "
            f"stage/encode∥flush pipeline has collapsed to serial")
    for r in rows:
        rb = bounds["per_kind"][r["kind"]]
        for stage, (lo, hi) in rb.get("stage_share_of_persist",
                                      {}).items():
            share = r[f"{stage}_s"] / max(r["persist_s"], 1e-9)
            if not lo <= share <= hi:
                problems.append(
                    f"{r['kind']}: {stage} share {share:.3f} outside "
                    f"baseline [{lo}, {hi}]")
        for stage in rb.get("required_stages", []):
            if r[f"{stage}_s"] <= 0:
                problems.append(
                    f"{r['kind']}: required stage {stage!r} recorded "
                    f"no busy time — instrumentation regressed")
    if problems:
        print("fig_breakdown REGRESSION:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"fig_breakdown check OK: overlap="
          f"{meta['overall_overlap_fraction']:.3f} "
          f"lanes={meta['rank_lanes']}")
    return 0


def summarize(rows) -> List[str]:
    lines = []
    for r in rows:
        lines.append(
            f"fig_breakdown/{r['kind']},{r['persist_s'] * 1e6:.0f},"
            f"d2h={r['d2h_s'] * 1e3:.0f}ms "
            f"encode={r['encode_s'] * 1e3:.0f}ms "
            f"flush={r['flush_s'] * 1e3:.0f}ms "
            f"commit={r['commit_s'] * 1e3:.1f}ms "
            f"overlap={r['overlap_fraction']:.2f}")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="gate against the committed baseline bounds "
                         "(exit 1 on regression)")
    args = ap.parse_args(argv)
    if args.check:
        return check(quick=True)
    for line in summarize(run(quick=args.quick)):
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
